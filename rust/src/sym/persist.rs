//! Relocatable, versioned binary codec for hash-consed term graphs — the
//! subsystem that lets [`crate::emu::EmulationResult`]s persist across
//! processes.
//!
//! Term graphs are interner-relative: a `SymId`/`UfId` is an index into
//! the *session* interner and a `TermId` an index into the emulation's
//! arena, so raw ids written by one process are meaningless in another.
//! This codec emits a **self-contained image** instead:
//!
//! * a local name table — every symbol / UF name the reachable graph
//!   uses, spelled out as strings;
//! * the reachable term nodes in topological order (the arena's
//!   interning order is topological by construction), children referenced
//!   by *local* indices that must precede the node — acyclicity is a
//!   construction invariant of the format, not a post-hoc check;
//! * every root the result references: register values are not needed
//!   (flows are finished), but memory-trace addresses/values, path
//!   conditions (assumption atoms) and the `tid` symbol are;
//! * an [`crate::util::Fnv128`] checksum over the whole payload.
//!
//! Decoding **relocates** into the loading session: names are re-interned
//! through the current [`SessionInterner`], nodes re-hash-consed into a
//! fresh [`TermPool`] via the smart constructors
//! ([`TermPool::rebuild`]), so structural sharing and term identities are
//! rebuilt — never trusted from disk. Every index is bounds-checked and
//! any malformed byte yields `None`, which the pipeline's disk store
//! treats exactly like a corrupt artifact: delete, count, recompute.

use crate::emu::{
    EmuError, EmuStats, EmulationResult, Flow, FlowEnd, FlowResult, Limits, PartialEmulation,
};
use crate::emu::env::RegEnv;
use crate::emu::memtrace::MemTrace;
use crate::sym::solver::{Assumptions, AssumptionsImage, FormImage};
use crate::sym::term::{BvOp, CmpKind, Node, SessionInterner, TermId, TermPool};
use crate::util::{Dec, Enc, Fnv128, FnvBuild, FnvMap};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// Bump when the image layout changes. The pipeline store's own version
/// guards the container; this one guards the term-graph encoding proper,
/// so a future store-format bump that leaves the graph codec untouched
/// can keep old images readable.
/// v2: memory-trace records carry the barrier `phase` id.
/// v3: a completeness tag follows the version — complete images keep the
///     v2 body; *partial* images additionally carry the budget-stopped
///     frontier (pending flows with live register environments, the
///     structural memo table, the stop limits and error) so a widened
///     retry resumes exploration instead of re-emulating flow zero.
pub const PERSIST_VERSION: u32 = 3;

/// Completeness tags (byte after the version word).
const TAG_COMPLETE: u8 = 0;
const TAG_PARTIAL: u8 = 1;

// ---------------------------------------------------------------------------
// Stable operator tags (shared with the simulator's DecodedKernel codec)
// ---------------------------------------------------------------------------

pub(crate) fn bvop_tag(op: BvOp) -> u8 {
    match op {
        BvOp::Add => 0,
        BvOp::Sub => 1,
        BvOp::Mul => 2,
        BvOp::UDiv => 3,
        BvOp::SDiv => 4,
        BvOp::URem => 5,
        BvOp::SRem => 6,
        BvOp::And => 7,
        BvOp::Or => 8,
        BvOp::Xor => 9,
        BvOp::Shl => 10,
        BvOp::LShr => 11,
        BvOp::AShr => 12,
        BvOp::UMin => 13,
        BvOp::UMax => 14,
        BvOp::SMin => 15,
        BvOp::SMax => 16,
    }
}

pub(crate) fn bvop_from_tag(tag: u8) -> Option<BvOp> {
    Some(match tag {
        0 => BvOp::Add,
        1 => BvOp::Sub,
        2 => BvOp::Mul,
        3 => BvOp::UDiv,
        4 => BvOp::SDiv,
        5 => BvOp::URem,
        6 => BvOp::SRem,
        7 => BvOp::And,
        8 => BvOp::Or,
        9 => BvOp::Xor,
        10 => BvOp::Shl,
        11 => BvOp::LShr,
        12 => BvOp::AShr,
        13 => BvOp::UMin,
        14 => BvOp::UMax,
        15 => BvOp::SMin,
        16 => BvOp::SMax,
        _ => return None,
    })
}

pub(crate) fn cmp_tag(k: CmpKind) -> u8 {
    match k {
        CmpKind::Eq => 0,
        CmpKind::Ne => 1,
        CmpKind::Ult => 2,
        CmpKind::Ule => 3,
        CmpKind::Ugt => 4,
        CmpKind::Uge => 5,
        CmpKind::Slt => 6,
        CmpKind::Sle => 7,
        CmpKind::Sgt => 8,
        CmpKind::Sge => 9,
    }
}

pub(crate) fn cmp_from_tag(tag: u8) -> Option<CmpKind> {
    Some(match tag {
        0 => CmpKind::Eq,
        1 => CmpKind::Ne,
        2 => CmpKind::Ult,
        3 => CmpKind::Ule,
        4 => CmpKind::Ugt,
        5 => CmpKind::Uge,
        6 => CmpKind::Slt,
        7 => CmpKind::Sle,
        8 => CmpKind::Sgt,
        9 => CmpKind::Sge,
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// Encoding: reachability + image writer
// ---------------------------------------------------------------------------

/// Collects the set of terms reachable from the registered roots.
#[derive(Debug)]
pub struct GraphBuilder<'p> {
    pool: &'p TermPool,
    /// FNV-hashed (the ids are small integers; this runs once per
    /// reachable node on every cache-miss emulation).
    seen: HashSet<u32, FnvBuild>,
}

impl<'p> GraphBuilder<'p> {
    pub fn new(pool: &'p TermPool) -> GraphBuilder<'p> {
        GraphBuilder {
            pool,
            seen: HashSet::default(),
        }
    }

    /// Mark `t` and everything it references (iterative DFS — address
    /// chains in unrolled kernels can be deep).
    pub fn add_root(&mut self, t: TermId) {
        let mut stack = vec![t];
        while let Some(t) = stack.pop() {
            if !self.seen.insert(t.0) {
                continue;
            }
            match self.pool.node(t) {
                Node::Const { .. } | Node::Sym { .. } => {}
                Node::Uf { args, .. } => stack.extend(args.iter().copied()),
                Node::Bin { a, b, .. } | Node::Cmp { a, b, .. } => {
                    stack.push(*a);
                    stack.push(*b);
                }
                Node::Not { a, .. }
                | Node::SExt { a, .. }
                | Node::ZExt { a, .. }
                | Node::Trunc { a, .. } => stack.push(*a),
                Node::Ite { cond, t: tt, e, .. } => {
                    stack.push(*cond);
                    stack.push(*tt);
                    stack.push(*e);
                }
            }
        }
    }

    /// Freeze the reachable set into an encodable image: nodes in
    /// ascending arena order (topological), local indices assigned.
    pub fn seal(self) -> GraphImage<'p> {
        let mut order: Vec<u32> = self.seen.into_iter().collect();
        order.sort_unstable();
        let index: FnvMap<u32, u32> = order
            .iter()
            .enumerate()
            .map(|(i, &t)| (t, i as u32))
            .collect();
        GraphImage {
            pool: self.pool,
            order,
            index,
        }
    }
}

/// A sealed, encodable view of a reachable term subgraph.
#[derive(Debug)]
pub struct GraphImage<'p> {
    pool: &'p TermPool,
    order: Vec<u32>,
    index: FnvMap<u32, u32>,
}

impl GraphImage<'_> {
    /// Local index of a registered root (panics on an unregistered term —
    /// an internal invariant violation, not an input condition).
    pub fn local(&self, t: TermId) -> u32 {
        *self
            .index
            .get(&t.0)
            .expect("term was not registered as a graph root")
    }

    /// Number of nodes in the image.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Write the name tables and the topologically ordered node list.
    pub fn encode(&self, e: &mut Enc) {
        // local name tables, in first-use order
        let mut sym_local: FnvMap<u32, u32> = FnvMap::default();
        let mut sym_names: Vec<&str> = Vec::new();
        let mut uf_local: FnvMap<u32, u32> = FnvMap::default();
        let mut uf_names: Vec<&str> = Vec::new();
        for &t in &self.order {
            match self.pool.node(TermId(t)) {
                Node::Sym { sym, .. } => {
                    sym_local.entry(sym.0).or_insert_with(|| {
                        sym_names.push(self.pool.sym_name(*sym));
                        (sym_names.len() - 1) as u32
                    });
                }
                Node::Uf { func, .. } => {
                    uf_local.entry(func.0).or_insert_with(|| {
                        uf_names.push(self.pool.uf_name(*func));
                        (uf_names.len() - 1) as u32
                    });
                }
                _ => {}
            }
        }
        e.u64(sym_names.len() as u64);
        for n in &sym_names {
            e.str(n);
        }
        e.u64(uf_names.len() as u64);
        for n in &uf_names {
            e.str(n);
        }

        e.u64(self.order.len() as u64);
        for &t in &self.order {
            match self.pool.node(TermId(t)) {
                Node::Const { bits, width } => {
                    e.u8(0);
                    e.u64(*bits);
                    e.u32(*width);
                }
                Node::Sym { sym, width } => {
                    e.u8(1);
                    e.u32(sym_local[&sym.0]);
                    e.u32(*width);
                }
                Node::Uf { func, args, width } => {
                    e.u8(2);
                    e.u32(uf_local[&func.0]);
                    e.u32(*width);
                    e.u64(args.len() as u64);
                    for a in args {
                        e.u32(self.local(*a));
                    }
                }
                Node::Bin { op, a, b, width } => {
                    e.u8(3);
                    e.u8(bvop_tag(*op));
                    e.u32(self.local(*a));
                    e.u32(self.local(*b));
                    e.u32(*width);
                }
                Node::Not { a, width } => {
                    e.u8(4);
                    e.u32(self.local(*a));
                    e.u32(*width);
                }
                Node::Cmp { kind, a, b } => {
                    e.u8(5);
                    e.u8(cmp_tag(*kind));
                    e.u32(self.local(*a));
                    e.u32(self.local(*b));
                }
                Node::Ite { cond, t: tt, e: el, width } => {
                    e.u8(6);
                    e.u32(self.local(*cond));
                    e.u32(self.local(*tt));
                    e.u32(self.local(*el));
                    e.u32(*width);
                }
                Node::SExt { a, from, width } => {
                    e.u8(7);
                    e.u32(self.local(*a));
                    e.u32(*from);
                    e.u32(*width);
                }
                Node::ZExt { a, from, width } => {
                    e.u8(8);
                    e.u32(self.local(*a));
                    e.u32(*from);
                    e.u32(*width);
                }
                Node::Trunc { a, width } => {
                    e.u8(9);
                    e.u32(self.local(*a));
                    e.u32(*width);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Decoding: relocation into the loading session
// ---------------------------------------------------------------------------

/// Local-index → relocated-`TermId` map produced by [`decode_graph`].
#[derive(Debug)]
pub struct GraphReader {
    map: Vec<TermId>,
}

impl GraphReader {
    /// Relocated id of local node `i` (bounds-checked).
    pub fn term(&self, i: u32) -> Option<TermId> {
        self.map.get(i as usize).copied()
    }
}

/// Read one graph image, re-interning names through `pool`'s session and
/// re-hash-consing every node into `pool`. Returns `None` on any
/// malformed byte (unknown tag, forward/out-of-range child reference,
/// width mismatch, bad UTF-8).
pub fn decode_graph(d: &mut Dec, pool: &mut TermPool) -> Option<GraphReader> {
    let nsyms = d.len()?;
    let mut sym_names = Vec::with_capacity(nsyms);
    for _ in 0..nsyms {
        sym_names.push(d.str()?);
    }
    let nufs = d.len()?;
    let mut uf_names = Vec::with_capacity(nufs);
    for _ in 0..nufs {
        uf_names.push(d.str()?);
    }

    let nnodes = d.len()?;
    let mut map: Vec<TermId> = Vec::with_capacity(nnodes);
    // children must precede their parent: only already-decoded locals
    // resolve, which makes the graph acyclic by construction
    for _ in 0..nnodes {
        let child = |i: u32, map: &[TermId]| -> Option<TermId> { map.get(i as usize).copied() };
        let wok = |w: u32| (1..=128).contains(&w);
        let id = match d.u8()? {
            0 => {
                let bits = d.u64()?;
                let width = d.u32()?;
                wok(width).then(|| pool.constant(bits, width))?
            }
            1 => {
                let name = *sym_names.get(d.u32()? as usize)?;
                let width = d.u32()?;
                wok(width).then(|| pool.symbol(name, width))?
            }
            2 => {
                let name = *uf_names.get(d.u32()? as usize)?;
                let width = d.u32()?;
                let nargs = d.len()?;
                let mut args = Vec::with_capacity(nargs);
                for _ in 0..nargs {
                    args.push(child(d.u32()?, &map)?);
                }
                wok(width).then(|| pool.uf(name, args, width))?
            }
            3 => {
                let op = bvop_from_tag(d.u8()?)?;
                let a = child(d.u32()?, &map)?;
                let b = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Bin { op, a, b, width })?
            }
            4 => {
                let a = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Not { a, width })?
            }
            5 => {
                let kind = cmp_from_tag(d.u8()?)?;
                let a = child(d.u32()?, &map)?;
                let b = child(d.u32()?, &map)?;
                pool.rebuild(&Node::Cmp { kind, a, b })?
            }
            6 => {
                let cond = child(d.u32()?, &map)?;
                let t = child(d.u32()?, &map)?;
                let e = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Ite { cond, t, e, width })?
            }
            7 => {
                let a = child(d.u32()?, &map)?;
                let from = d.u32()?;
                let width = d.u32()?;
                pool.rebuild(&Node::SExt { a, from, width })?
            }
            8 => {
                let a = child(d.u32()?, &map)?;
                let from = d.u32()?;
                let width = d.u32()?;
                pool.rebuild(&Node::ZExt { a, from, width })?
            }
            9 => {
                let a = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Trunc { a, width })?
            }
            _ => return None,
        };
        map.push(id);
    }
    Some(GraphReader { map })
}

// ---------------------------------------------------------------------------
// EmulationResult codec
// ---------------------------------------------------------------------------

fn encode_assumptions(e: &mut Enc, img: &AssumptionsImage, g: &GraphImage) {
    e.u64(img.forms.len() as u64);
    for f in &img.forms {
        e.u64(f.atoms.len() as u64);
        for &(t, c) in &f.atoms {
            e.u32(g.local(t));
            e.i128(c);
        }
        for bound in [f.lo, f.hi] {
            match bound {
                None => e.u8(0),
                Some(v) => {
                    e.u8(1);
                    e.i128(v);
                }
            }
        }
        e.u64(f.ne.len() as u64);
        for &v in &f.ne {
            e.i128(v);
        }
        e.bool(f.nonneg);
    }
    e.u64(img.opaque.len() as u64);
    for &(t, v) in &img.opaque {
        e.u32(g.local(t));
        e.bool(v);
    }
}

fn decode_assumptions(d: &mut Dec, g: &GraphReader) -> Option<Assumptions> {
    let nforms = d.len()?;
    let mut forms = Vec::with_capacity(nforms);
    for _ in 0..nforms {
        let natoms = d.len()?;
        let mut atoms = Vec::with_capacity(natoms);
        for _ in 0..natoms {
            let t = g.term(d.u32()?)?;
            atoms.push((t, d.i128()?));
        }
        let mut bounds = [None, None];
        for b in bounds.iter_mut() {
            *b = match d.u8()? {
                0 => None,
                1 => Some(d.i128()?),
                _ => return None,
            };
        }
        let nne = d.len()?;
        let mut ne = Vec::with_capacity(nne);
        for _ in 0..nne {
            ne.push(d.i128()?);
        }
        forms.push(FormImage {
            atoms,
            lo: bounds[0],
            hi: bounds[1],
            ne,
            nonneg: d.bool()?,
        });
    }
    let nopaque = d.len()?;
    let mut opaque = Vec::with_capacity(nopaque);
    for _ in 0..nopaque {
        let t = g.term(d.u32()?)?;
        opaque.push((t, d.bool()?));
    }
    Some(Assumptions::from_image(AssumptionsImage { forms, opaque }))
}

/// Serialize a whole emulation result as a self-contained, relocatable
/// image (version ∥ graph ∥ result shape ∥ `Fnv128` checksum).
pub fn encode_emulation(r: &EmulationResult) -> Vec<u8> {
    // snapshot the assumption sets once: the images both supply the
    // graph roots and get encoded verbatim afterwards
    let images: Vec<AssumptionsImage> = r.flows.iter().map(|f| f.assumptions.export()).collect();

    let mut b = GraphBuilder::new(&r.pool);
    b.add_root(r.tid_sym);
    let mut roots = Vec::new();
    for f in &r.flows {
        f.trace.term_roots(&mut roots);
    }
    for img in &images {
        for form in &img.forms {
            roots.extend(form.atoms.iter().map(|&(t, _)| t));
        }
        roots.extend(img.opaque.iter().map(|&(t, _)| t));
    }
    for t in roots {
        b.add_root(t);
    }
    let g = b.seal();

    let mut e = Enc::default();
    e.u32(PERSIST_VERSION);
    e.u8(TAG_COMPLETE);
    g.encode(&mut e);
    e.u32(g.local(r.tid_sym));
    for w in r.stats.to_words() {
        e.u64(w);
    }
    e.u64(r.flows.len() as u64);
    for (f, img) in r.flows.iter().zip(&images) {
        e.u32(f.id);
        e.u8(f.end.tag());
        f.trace.encode(&mut e, &mut |t| g.local(t));
        encode_assumptions(&mut e, img, &g);
    }
    seal_checksum(e)
}

/// Append the `Fnv128` trailer and hand back the finished image bytes.
fn seal_checksum(mut e: Enc) -> Vec<u8> {
    let (c0, c1) = {
        let mut h = Fnv128::new();
        h.write(&e.buf);
        h.finish()
    };
    e.u64(c0);
    e.u64(c1);
    e.buf
}

/// Verify the `Fnv128` trailer and the version word, returning a decoder
/// over the body (positioned after the version) plus the completeness tag.
fn open_image(bytes: &[u8]) -> Option<(Dec<'_>, u8)> {
    if bytes.len() < 16 {
        return None;
    }
    let (body, tail) = bytes.split_at(bytes.len() - 16);
    let want = {
        let mut h = Fnv128::new();
        h.write(body);
        h.finish()
    };
    let mut td = Dec::new(tail);
    if (td.u64()?, td.u64()?) != want {
        return None;
    }
    let mut d = Dec::new(body);
    if d.u32()? != PERSIST_VERSION {
        return None;
    }
    let tag = d.u8()?;
    Some((d, tag))
}

/// Decode an emulation image into the *loading* session: a fresh
/// [`TermPool`] is grown in `session`, every name re-interned, every node
/// re-hash-consed. Any checksum/bounds/shape violation returns `None`
/// (the caller recomputes, exactly like other corrupt artifacts).
pub fn decode_emulation(
    bytes: &[u8],
    session: &Arc<SessionInterner>,
) -> Option<EmulationResult> {
    let (mut d, tag) = open_image(bytes)?;
    if tag != TAG_COMPLETE {
        return None;
    }
    let mut pool = TermPool::in_session(session.clone());
    let g = decode_graph(&mut d, &mut pool)?;
    let tid_sym = g.term(d.u32()?)?;
    let mut words = [0u64; 12];
    for w in words.iter_mut() {
        *w = d.u64()?;
    }
    let stats = EmuStats::from_words(words);
    let nflows = d.len()?;
    let mut flows = Vec::with_capacity(nflows);
    for _ in 0..nflows {
        let id = d.u32()?;
        let end = FlowEnd::from_tag(d.u8()?)?;
        let trace = crate::emu::memtrace::MemTrace::decode(&mut d, &|i| g.term(i))?;
        let assumptions = decode_assumptions(&mut d, &g)?;
        flows.push(FlowResult {
            id,
            trace,
            assumptions,
            end,
        });
    }
    d.done().then_some(EmulationResult {
        pool,
        flows,
        tid_sym,
        stats,
    })
}

// ---------------------------------------------------------------------------
// PartialEmulation codec (resumable frontier images)
// ---------------------------------------------------------------------------

fn encode_error(e: &mut Enc, err: &EmuError) {
    match err {
        EmuError::FlowLimit(n) => {
            e.u8(0);
            e.u64(*n as u64);
        }
        EmuError::StepLimit => e.u8(1),
        EmuError::UnknownLabel(l) => {
            e.u8(2);
            e.str(l);
        }
    }
}

fn decode_error(d: &mut Dec) -> Option<EmuError> {
    Some(match d.u8()? {
        0 => EmuError::FlowLimit(usize::try_from(d.u64()?).ok()?),
        1 => EmuError::StepLimit,
        2 => EmuError::UnknownLabel(d.str()?.to_string()),
        _ => return None,
    })
}

/// Serialize a budget-stopped frontier as a self-contained, relocatable
/// image. Layout: version ∥ `TAG_PARTIAL` ∥ graph ∥ tid ∥ stats ∥ limits ∥
/// error ∥ done flows ∥ pending flows (with live register environments) ∥
/// memo table ∥ next flow id ∥ `Fnv128` checksum.
pub fn encode_partial_emulation(p: &PartialEmulation) -> Vec<u8> {
    let done_images: Vec<AssumptionsImage> =
        p.done.iter().map(|f| f.assumptions.export()).collect();
    let pending_images: Vec<AssumptionsImage> =
        p.pending.iter().map(|f| f.assumptions.export()).collect();

    let mut b = GraphBuilder::new(&p.pool);
    b.add_root(p.tid_sym);
    let mut roots = Vec::new();
    for f in &p.done {
        f.trace.term_roots(&mut roots);
    }
    for f in &p.pending {
        f.trace.term_roots(&mut roots);
        roots.extend(f.env.vals.iter().flatten().copied());
    }
    for img in done_images.iter().chain(&pending_images) {
        for form in &img.forms {
            roots.extend(form.atoms.iter().map(|&(t, _)| t));
        }
        roots.extend(img.opaque.iter().map(|&(t, _)| t));
    }
    for t in roots {
        b.add_root(t);
    }
    let g = b.seal();

    let mut e = Enc::default();
    e.u32(PERSIST_VERSION);
    e.u8(TAG_PARTIAL);
    g.encode(&mut e);
    e.u32(g.local(p.tid_sym));
    for w in p.stats.to_words() {
        e.u64(w);
    }
    e.u64(p.limits.max_flows as u64);
    e.u64(p.limits.max_steps_per_flow);
    e.u64(p.limits.max_total_steps);
    encode_error(&mut e, &p.error);

    e.u64(p.done.len() as u64);
    for (f, img) in p.done.iter().zip(&done_images) {
        e.u32(f.id);
        e.u8(f.end.tag());
        f.trace.encode(&mut e, &mut |t| g.local(t));
        encode_assumptions(&mut e, img, &g);
    }

    e.u64(p.pending.len() as u64);
    for (f, img) in p.pending.iter().zip(&pending_images) {
        e.u32(f.id);
        e.u64(f.pc as u64);
        e.u32(f.segment);
        e.u32(f.phase);
        e.u64(f.steps);
        // entered_loops sorted by header so the bytes are deterministic
        let mut loops: Vec<(usize, u32)> =
            f.entered_loops.iter().map(|(&h, &c)| (h, c)).collect();
        loops.sort_unstable();
        e.u64(loops.len() as u64);
        for (header, count) in loops {
            e.u64(header as u64);
            e.u32(count);
        }
        e.u64(f.env.vals.len() as u64);
        for v in &f.env.vals {
            match v {
                None => e.u8(0),
                Some(t) => {
                    e.u8(1);
                    e.u32(g.local(*t));
                }
            }
        }
        f.trace.encode(&mut e, &mut |t| g.local(t));
        encode_assumptions(&mut e, img, &g);
    }

    e.u64(p.memo.len() as u64);
    for &(pc, fp) in &p.memo {
        e.u64(pc as u64);
        e.u64(fp);
    }
    e.u32(p.next_flow_id);
    seal_checksum(e)
}

/// Decode a frontier image into the *loading* session. `nregs` is the
/// register count of the kernel the caller is about to resume
/// ([`crate::emu::env::RegInterner::from_kernel`] is deterministic per
/// kernel, so slot indices are stable cross-process); any environment
/// whose length disagrees fails the decode — the image belongs to a
/// different kernel than the key promised. Pass `None` for a purely
/// structural check (the store's verify audit has no kernel in hand).
pub fn decode_partial_emulation(
    bytes: &[u8],
    session: &Arc<SessionInterner>,
    nregs: Option<usize>,
) -> Option<PartialEmulation> {
    let (mut d, tag) = open_image(bytes)?;
    if tag != TAG_PARTIAL {
        return None;
    }
    let mut pool = TermPool::in_session(session.clone());
    let g = decode_graph(&mut d, &mut pool)?;
    let tid_sym = g.term(d.u32()?)?;
    let mut words = [0u64; 12];
    for w in words.iter_mut() {
        *w = d.u64()?;
    }
    let stats = EmuStats::from_words(words);
    let limits = Limits {
        max_flows: usize::try_from(d.u64()?).ok()?,
        max_steps_per_flow: d.u64()?,
        max_total_steps: d.u64()?,
    };
    let error = decode_error(&mut d)?;

    let ndone = d.len()?;
    let mut done = Vec::with_capacity(ndone);
    for _ in 0..ndone {
        let id = d.u32()?;
        let end = FlowEnd::from_tag(d.u8()?)?;
        let trace = MemTrace::decode(&mut d, &|i| g.term(i))?;
        let assumptions = decode_assumptions(&mut d, &g)?;
        done.push(FlowResult {
            id,
            trace,
            assumptions,
            end,
        });
    }

    let npending = d.len()?;
    let mut pending = Vec::with_capacity(npending);
    for _ in 0..npending {
        let id = d.u32()?;
        let pc = usize::try_from(d.u64()?).ok()?;
        let segment = d.u32()?;
        let phase = d.u32()?;
        let steps = d.u64()?;
        let nloops = d.len()?;
        let mut entered_loops = HashMap::with_capacity(nloops);
        for _ in 0..nloops {
            let header = usize::try_from(d.u64()?).ok()?;
            entered_loops.insert(header, d.u32()?);
        }
        let nvals = d.len()?;
        if nregs.is_some_and(|n| nvals != n) {
            return None;
        }
        let mut env = RegEnv::new(nvals);
        for slot in env.vals.iter_mut() {
            *slot = match d.u8()? {
                0 => None,
                1 => Some(g.term(d.u32()?)?),
                _ => return None,
            };
        }
        let trace = MemTrace::decode(&mut d, &|i| g.term(i))?;
        let assumptions = decode_assumptions(&mut d, &g)?;
        pending.push(Flow {
            id,
            env,
            assumptions,
            trace,
            pc,
            segment,
            phase,
            entered_loops,
            steps,
        });
    }

    let nmemo = d.len()?;
    let mut memo = Vec::with_capacity(nmemo);
    for _ in 0..nmemo {
        let pc = usize::try_from(d.u64()?).ok()?;
        memo.push((pc, d.u64()?));
    }
    let next_flow_id = d.u32()?;
    d.done().then_some(PartialEmulation {
        pool,
        tid_sym,
        stats,
        limits,
        done,
        pending,
        memo,
        next_flow_id,
        error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::emulate_in_session;
    use crate::emu::Limits;
    use crate::ptx::parser::parse_kernel;
    use crate::sym::term::{eval, SymId, UfId};
    use crate::util::{check_cases, fnv64, Rng};

    /// Name-keyed evaluation environment: identical values in any pool
    /// that spells the same names, whatever the local ids are.
    fn eval_by_name(pool: &TermPool, t: TermId, seed: u64) -> u64 {
        let sym_val = |s: SymId| {
            let mut r = Rng::new(seed ^ fnv64(pool.sym_name(s).as_bytes()));
            r.next_u64()
        };
        let uf_val = |f: UfId, args: &[u64]| {
            let mut h = seed ^ fnv64(pool.uf_name(f).as_bytes());
            for &a in args {
                h = h.rotate_left(13) ^ a.wrapping_mul(0x100000001B3);
            }
            h
        };
        eval(pool, t, &sym_val, &uf_val)
    }

    fn random_term(p: &mut TermPool, rng: &mut Rng, depth: u32, width: u32) -> TermId {
        if depth == 0 || rng.below(5) == 0 {
            return match rng.below(4) {
                0 => p.constant(rng.next_u64(), width),
                1 => p.symbol(&format!("s{}", rng.below(5)), width),
                _ => {
                    // UFs of arity 0..=2 over mixed-width args
                    let arity = rng.below(3) as usize;
                    let args = (0..arity)
                        .map(|_| {
                            let w = *rng.pick(&[8u32, 16, 32, 64]);
                            p.symbol(&format!("a{}", rng.below(3)), w)
                        })
                        .collect();
                    p.uf(&format!("f{}", rng.below(3)), args, width)
                }
            };
        }
        match rng.below(8) {
            0 => {
                let from = match width {
                    64 => 32,
                    32 => 16,
                    _ => 8,
                };
                if from < width {
                    let a = random_term(p, rng, depth - 1, from);
                    return if rng.below(2) == 0 {
                        p.sext(a, width)
                    } else {
                        p.zext(a, width)
                    };
                }
            }
            1 => {
                let wider = if width < 64 { 64 } else { 128 };
                let a = random_term(p, rng, depth - 1, wider);
                return p.trunc(a, width);
            }
            2 => {
                let w = *rng.pick(&[8u32, 16, 32, 64]);
                let a = random_term(p, rng, depth - 1, w);
                let b = random_term(p, rng, depth - 1, w);
                let kind = cmp_from_tag(rng.below(10) as u8).unwrap();
                let c = p.cmp(kind, a, b);
                let t = random_term(p, rng, depth - 1, width);
                let e = random_term(p, rng, depth - 1, width);
                return p.ite(c, t, e);
            }
            3 => {
                let a = random_term(p, rng, depth - 1, width);
                return p.not(a);
            }
            _ => {}
        }
        let a = random_term(p, rng, depth - 1, width);
        let b = random_term(p, rng, depth - 1, width);
        let op = bvop_from_tag(rng.below(17) as u8).unwrap();
        p.bin(op, a, b)
    }

    fn roundtrip_graph(src: &TermPool, roots: &[TermId], dst: &mut TermPool) -> Vec<TermId> {
        let mut b = GraphBuilder::new(src);
        for &r in roots {
            b.add_root(r);
        }
        let g = b.seal();
        let mut e = Enc::default();
        g.encode(&mut e);
        let locals: Vec<u32> = roots.iter().map(|&r| g.local(r)).collect();
        let mut d = Dec::new(&e.buf);
        let r = decode_graph(&mut d, dst).expect("decode of a fresh encoding");
        assert!(d.done(), "trailing bytes after graph");
        locals.iter().map(|&l| r.term(l).unwrap()).collect()
    }

    /// Round-trip over randomized graphs: eval agreement on every root,
    /// across sessions, with the destination interner polluted so every
    /// `SymId`/`UfId`/`TermId` is numerically different.
    #[test]
    fn prop_roundtrip_eval_agreement() {
        check_cases("persist-roundtrip-eval", 200, |rng| {
            let mut src = TermPool::new();
            let width = *rng.pick(&[8u32, 16, 32, 64]);
            let roots: Vec<TermId> = (0..1 + rng.below(4))
                .map(|_| random_term(&mut src, rng, 4, width))
                .collect();

            // destination session polluted with unrelated names
            let session = Arc::new(SessionInterner::new());
            let mut dst = TermPool::in_session(session);
            for i in 0..10 {
                dst.symbol(&format!("noise{i}"), 32);
                dst.uf(&format!("nf{i}"), vec![], 32);
            }

            let relocated = roundtrip_graph(&src, &roots, &mut dst);
            let seed = rng.next_u64();
            for (&r, &n) in roots.iter().zip(&relocated) {
                assert_eq!(
                    eval_by_name(&src, r, seed),
                    eval_by_name(&dst, n, seed),
                    "relocated root evaluates differently"
                );
                assert_eq!(src.width(r), dst.width(n), "width changed in relocation");
            }
        });
    }

    /// Structural sharing is rebuilt: the same root decoded twice into one
    /// pool lands on the same `TermId`.
    #[test]
    fn relocation_rehashconses() {
        let mut src = TermPool::new();
        let x = src.symbol("x", 32);
        let c = src.constant(7, 32);
        let t = src.bin(BvOp::Add, x, c);
        let u = src.uf("load", vec![t], 32);

        let mut dst = TermPool::new();
        let first = roundtrip_graph(&src, &[u, t], &mut dst);
        let len_after_first = dst.len();
        let second = roundtrip_graph(&src, &[u, t], &mut dst);
        assert_eq!(first, second, "re-decoding must re-hash-cons to the same ids");
        assert_eq!(dst.len(), len_after_first, "no duplicate nodes interned");
    }

    /// A full emulation survives the codec: encode in one session, decode
    /// into a *different* polluted session, and compare the result shape
    /// plus eval agreement on every memory-trace root.
    #[test]
    fn emulation_roundtrip_cross_session() {
        const K: &str = r#"
.visible .entry rt(.param .u64 out, .param .u64 a, .param .u32 n){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r1, %tid.x;
setp.ge.s32 %p1, %r1, %r5;
@%p1 bra $EXIT;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.f32 %f1, [%rd6];
ld.global.f32 %f2, [%rd6+4];
add.f32 %f3, %f1, %f2;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f3;
$EXIT: ret;
}
"#;
        let k = parse_kernel(K).unwrap();
        let fresh = emulate_in_session(
            &k,
            Limits::default(),
            Arc::new(SessionInterner::new()),
        )
        .unwrap();
        let bytes = encode_emulation(&fresh);

        // polluted loading session: every id is shifted
        let session = Arc::new(SessionInterner::new());
        {
            let mut warm = TermPool::in_session(session.clone());
            for i in 0..20 {
                warm.symbol(&format!("other{i}"), 32);
                warm.uf(&format!("of{i}"), vec![], 64);
            }
        }
        let loaded = decode_emulation(&bytes, &session).expect("image decodes");

        assert_eq!(loaded.flows.len(), fresh.flows.len());
        assert_eq!(loaded.stats.to_words(), fresh.stats.to_words());
        let seed = 0xC0FF_EE00_D15E_A5E5u64;
        assert_eq!(
            eval_by_name(&fresh.pool, fresh.tid_sym, seed),
            eval_by_name(&loaded.pool, loaded.tid_sym, seed)
        );
        for (a, b) in fresh.flows.iter().zip(&loaded.flows) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.end, b.end);
            assert_eq!(a.trace.loads.len(), b.trace.loads.len());
            assert_eq!(a.trace.stores.len(), b.trace.stores.len());
            assert_eq!(a.assumptions.fact_count(), b.assumptions.fact_count());
            for (la, lb) in a.trace.loads.iter().zip(&b.trace.loads) {
                assert_eq!((la.stmt, la.ty, la.space), (lb.stmt, lb.ty, lb.space));
                assert_eq!(
                    (la.nc, la.segment, la.guarded, la.valid),
                    (lb.nc, lb.segment, lb.guarded, lb.valid)
                );
                assert_eq!(
                    eval_by_name(&fresh.pool, la.addr, seed),
                    eval_by_name(&loaded.pool, lb.addr, seed),
                    "load address diverged"
                );
                assert_eq!(
                    eval_by_name(&fresh.pool, la.value, seed),
                    eval_by_name(&loaded.pool, lb.value, seed),
                    "load value diverged"
                );
            }
        }

        // the downstream consumer agrees: detection over the relocated
        // result is identical to detection over the fresh one
        let opts = crate::shuffle::DetectOpts::default();
        let d1 = crate::shuffle::detect(&k, &fresh, opts);
        let d2 = crate::shuffle::detect(&k, &loaded, opts);
        assert_eq!(d1.chosen, d2.chosen, "relocation changed detection");
        assert_eq!(d1.total_global_loads, d2.total_global_loads);
    }

    /// Relocated assumptions answer `check` like the originals even
    /// though every `TermId` was renumbered (key re-canonicalization).
    #[test]
    fn relocated_assumptions_still_decide() {
        use crate::sym::solver::Truth;
        let mut src = TermPool::new();
        let x = src.symbol("x", 32);
        let y = src.symbol("y", 32);
        let c100 = src.constant(100, 32);
        let lt = src.cmp(CmpKind::Slt, x, c100); // x < 100
        let xy = src.cmp(CmpKind::Slt, x, y); // x < y
        let mut a = Assumptions::new();
        a.assume(&src, lt, true).unwrap();
        a.assume(&src, xy, true).unwrap();

        // relocate the atoms and the image into a pool where y interns
        // *before* x, flipping the canonical atom order of `x - y`
        let session = Arc::new(SessionInterner::new());
        let mut dst = TermPool::in_session(session);
        dst.symbol("y", 32);
        dst.symbol("noise", 8);
        let mut b = GraphBuilder::new(&src);
        let img = a.export();
        for f in &img.forms {
            for &(t, _) in &f.atoms {
                b.add_root(t);
            }
        }
        for &(t, _) in &img.opaque {
            b.add_root(t);
        }
        let g = b.seal();
        let mut e = Enc::default();
        g.encode(&mut e);
        let mut enc2 = Enc::default();
        encode_assumptions(&mut enc2, &img, &g);
        let mut d = Dec::new(&e.buf);
        let r = decode_graph(&mut d, &mut dst).unwrap();
        let mut d2 = Dec::new(&enc2.buf);
        let reloc = decode_assumptions(&mut d2, &r).unwrap();

        let nx = dst.symbol("x", 32);
        let ny = dst.symbol("y", 32);
        let nc200 = dst.constant(200, 32);
        let nlt200 = dst.cmp(CmpKind::Slt, nx, nc200);
        assert_eq!(reloc.check(&dst, nlt200), Truth::True, "x < 100 ⇒ x < 200");
        let nyx = dst.cmp(CmpKind::Sgt, ny, nx);
        assert_eq!(reloc.check(&dst, nyx), Truth::True, "x < y ⇒ y > x");
    }

    /// Kernel with `bits` independent tid-bit branches → `2^bits` flows.
    fn forky_src(bits: u32) -> String {
        let mut body = String::new();
        for i in 0..bits {
            body.push_str(&format!(
                "and.b32 %r10, %r1, {};\nsetp.eq.s32 %p{}, %r10, 0;\n@%p{} bra $S{};\nadd.s32 %r2, %r2, {};\n$S{}:\n",
                1u32 << i,
                i + 1,
                i + 1,
                i,
                100 + i,
                i
            ));
        }
        format!(
            r#"
.visible .entry forky(.param .u64 out){{
.reg .b32 %r<12>; .reg .b64 %rd<4>; .reg .pred %p<8>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
mov.u32 %r2, 0;
{body}st.global.u32 [%rd2], %r2;
ret;
}}
"#
        )
    }

    /// A frontier image round-trips into a polluted session and resumes to
    /// the exact cold-wide result — the cross-process resume path the
    /// pipeline's widened retry uses.
    #[test]
    fn partial_roundtrip_resumes_to_cold_wide_result() {
        use crate::emu::env::RegInterner;
        use crate::emu::{emulate_outcome, resume_outcome, EmuOutcome};

        let k = parse_kernel(&forky_src(3)).unwrap();
        let tight = Limits {
            max_flows: 2,
            ..Limits::default()
        };
        let part = match emulate_outcome(&k, tight, Arc::new(SessionInterner::new()), None) {
            EmuOutcome::Partial(p) => *p,
            other => panic!("expected a partial outcome, got {other:?}"),
        };
        assert!(matches!(part.error, EmuError::FlowLimit(2)));
        let bytes = encode_partial_emulation(&part);

        // polluted loading session: every id is shifted
        let session = Arc::new(SessionInterner::new());
        {
            let mut warm = TermPool::in_session(session.clone());
            for i in 0..15 {
                warm.symbol(&format!("p{i}"), 32);
                warm.uf(&format!("pf{i}"), vec![], 64);
            }
        }
        let nregs = RegInterner::from_kernel(&k).len();
        let loaded =
            decode_partial_emulation(&bytes, &session, Some(nregs)).expect("image decodes");
        assert_eq!(loaded.pending.len(), part.pending.len());
        assert_eq!(loaded.memo, part.memo, "structural memo keys relocate verbatim");
        assert_eq!(loaded.next_flow_id, part.next_flow_id);
        assert_eq!(loaded.limits.max_flows, 2);

        let resumed = match resume_outcome(&k, Limits::default(), loaded, None) {
            EmuOutcome::Complete(r) => r,
            other => panic!("resume should complete, got {other:?}"),
        };
        let cold = emulate_in_session(&k, Limits::default(), Arc::new(SessionInterner::new()))
            .unwrap();
        assert_eq!(resumed.stats.to_words(), cold.stats.to_words());
        assert_eq!(resumed.flows.len(), cold.flows.len());
        for (a, b) in resumed.flows.iter().zip(&cold.flows) {
            assert_eq!((a.id, a.end), (b.id, b.end));
            assert_eq!(a.trace.loads.len(), b.trace.loads.len());
            assert_eq!(a.trace.stores.len(), b.trace.stores.len());
            assert_eq!(a.assumptions.fact_count(), b.assumptions.fact_count());
        }

        // a register-count mismatch means the image is for another kernel;
        // the structural (kernel-less) check still accepts it
        assert!(decode_partial_emulation(&bytes, &session, Some(nregs + 1)).is_none());
        assert!(nregs > 1 && decode_partial_emulation(&bytes, &session, Some(0)).is_none());
        assert!(decode_partial_emulation(&bytes, &session, None).is_some());
    }

    /// The completeness tag keeps the two image forms apart: a complete
    /// image never decodes as a frontier and vice versa.
    #[test]
    fn completeness_tag_separates_image_forms() {
        use crate::emu::env::RegInterner;
        use crate::emu::{emulate_outcome, EmuOutcome};

        let k = parse_kernel(&forky_src(2)).unwrap();
        let nregs = RegInterner::from_kernel(&k).len();
        let session = Arc::new(SessionInterner::new());

        let complete =
            emulate_in_session(&k, Limits::default(), Arc::new(SessionInterner::new())).unwrap();
        let cbytes = encode_emulation(&complete);
        assert!(decode_emulation(&cbytes, &session).is_some());
        assert!(decode_partial_emulation(&cbytes, &session, Some(nregs)).is_none());

        let tight = Limits {
            max_flows: 2,
            ..Limits::default()
        };
        let part = match emulate_outcome(&k, tight, Arc::new(SessionInterner::new()), None) {
            EmuOutcome::Partial(p) => *p,
            other => panic!("expected a partial outcome, got {other:?}"),
        };
        let pbytes = encode_partial_emulation(&part);
        assert!(decode_partial_emulation(&pbytes, &session, Some(nregs)).is_some());
        assert!(decode_emulation(&pbytes, &session).is_none());

        // corruption resistance mirrors the complete-image guarantee
        for cut in (0..pbytes.len()).step_by(11) {
            assert!(
                decode_partial_emulation(&pbytes[..cut], &session, Some(nregs)).is_none(),
                "truncation at {cut} must be rejected"
            );
        }
        for i in (0..pbytes.len()).step_by(13) {
            let mut bad = pbytes.clone();
            bad[i] ^= 0xFF;
            assert!(
                decode_partial_emulation(&bad, &session, Some(nregs)).is_none(),
                "bit flip at {i} must be rejected"
            );
        }
    }

    /// Corrupt and truncated images must fail decode, never panic.
    #[test]
    fn corrupt_and_truncated_images_are_rejected() {
        let k = parse_kernel(
            r#"
.visible .entry c(.param .u64 a){
.reg .b32 %r<4>; .reg .b64 %rd<4>; .reg .f32 %f<2>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd2, %rd2, %rd3;
ld.global.f32 %f1, [%rd2];
st.global.f32 [%rd2], %f1;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate_in_session(&k, Limits::default(), Arc::new(SessionInterner::new()))
            .unwrap();
        let bytes = encode_emulation(&r);
        let session = Arc::new(SessionInterner::new());
        assert!(decode_emulation(&bytes, &session).is_some());

        // every truncation fails cleanly
        for cut in 0..bytes.len() {
            assert!(
                decode_emulation(&bytes[..cut], &session).is_none(),
                "truncation at {cut} must be rejected"
            );
        }
        // every single-byte flip fails cleanly (checksum) — sample to
        // keep the test fast
        for i in (0..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 0xFF;
            assert!(
                decode_emulation(&bad, &session).is_none(),
                "bit flip at {i} must be rejected"
            );
        }
    }
}
