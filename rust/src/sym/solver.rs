//! SMT-lite: the two decision procedures PTXASW needs from its solver.
//!
//! The paper plugs Z3 in for (a) pruning unrealizable control-flow paths
//! under the recorded branch assumptions (§4.2) and (b) solving the shuffle
//! delta equation `A(%tid.x + N) = B(%tid.x)` (§5.1). Both queries, over
//! the address/guard arithmetic compilers emit, live in the linear fragment
//! — so this module implements a sound *incomplete* decision procedure on
//! affine normal forms: interval + disequality reasoning per linear form
//! for (a), and exact rational solving for (b).
//!
//! Soundness contract: `check` may answer `Unknown` freely, but must never
//! claim `True`/`False` for a satisfiable opposite — pruning a realizable
//! path would corrupt the memory trace. Unsigned comparisons are therefore
//! only decided through structural equality or constant folding unless the
//! linear form is known non-negative.

use super::affine::{extract, split_on, Affine};
use super::term::{CmpKind, Node, TermId, TermPool};
use std::collections::BTreeMap;

/// Three-valued answer of the assumption engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Truth {
    True,
    False,
    Unknown,
}

impl Truth {
    pub fn known(self) -> Option<bool> {
        match self {
            Truth::True => Some(true),
            Truth::False => Some(false),
            Truth::Unknown => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conflict;

impl std::fmt::Display for Conflict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "assumption conflicts with recorded facts")
    }
}

impl std::error::Error for Conflict {}

/// Canonical key of a linear form: its coefficient vector. Sign-normalized
/// so `x - y` and `y - x` share a key.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
struct FormKey(Vec<(TermId, i128)>);

/// Facts known about one linear form `g` (the non-constant part).
#[derive(Debug, Clone, Default)]
struct FormFacts {
    lo: Option<i128>,
    hi: Option<i128>,
    ne: Vec<i128>,
    /// Known non-negative even without explicit bounds (e.g. zext provenance).
    nonneg: bool,
}

impl FormFacts {
    fn admits(&self, v: i128) -> bool {
        if let Some(lo) = self.lo {
            if v < lo {
                return false;
            }
        }
        if let Some(hi) = self.hi {
            if v > hi {
                return false;
            }
        }
        !self.ne.contains(&v)
    }

    fn fixed(&self) -> Option<i128> {
        match (self.lo, self.hi) {
            (Some(l), Some(h)) if l == h => Some(l),
            _ => None,
        }
    }
}

/// A set of branch assumptions with conflict detection (paper §4.2).
#[derive(Debug, Clone, Default)]
pub struct Assumptions {
    /// Linear facts per canonical form.
    forms: BTreeMap<FormKey, FormFacts>,
    /// Opaque predicate facts (non-linear / unsigned-undecidable preds).
    opaque: BTreeMap<TermId, bool>,
}

/// One normalized constraint: `g + c ⋈ 0` under signed semantics where `g`
/// is keyed by `key` (after sign normalization `flip` applies).
struct Linear {
    key: FormKey,
    /// Constant after normalization: constraint is `g ⋈ rhs`.
    rhs: i128,
    kind: CmpKind,
}

fn canonicalize(f: &Affine, kind: CmpKind) -> Linear {
    let mut coeffs: Vec<(TermId, i128)> = f.coeffs.iter().map(|(&t, &c)| (t, c)).collect();
    let mut rhs = -f.constant; // g + c ⋈ 0  ⇔  g ⋈ -c
    let mut kind = kind;
    let flip = coeffs.first().map(|&(_, c)| c < 0).unwrap_or(false);
    if flip {
        for e in coeffs.iter_mut() {
            e.1 = -e.1;
        }
        rhs = -rhs;
        kind = match kind {
            CmpKind::Slt => CmpKind::Sgt,
            CmpKind::Sle => CmpKind::Sge,
            CmpKind::Sgt => CmpKind::Slt,
            CmpKind::Sge => CmpKind::Sle,
            CmpKind::Ult => CmpKind::Ugt,
            CmpKind::Ule => CmpKind::Uge,
            CmpKind::Ugt => CmpKind::Ult,
            CmpKind::Uge => CmpKind::Ule,
            k => k,
        };
    }
    Linear {
        key: FormKey(coeffs),
        rhs,
        kind,
    }
}

/// Is the linear form syntactically non-negative (all atoms known-unsigned
/// with non-negative coefficients and non-negative constant)? Used to admit
/// unsigned comparisons into the signed interval engine.
fn form_nonneg(pool: &TermPool, f: &Affine) -> bool {
    if f.constant < 0 {
        return false;
    }
    f.coeffs
        .iter()
        .all(|(&t, &c)| c >= 0 && matches!(pool.node(t), Node::ZExt { .. }))
}

impl Assumptions {
    pub fn new() -> Assumptions {
        Assumptions::default()
    }

    /// Normalize a width-1 predicate term into a linear constraint when the
    /// comparison kind is decidable in the signed affine domain.
    fn linearize(&self, pool: &TermPool, p: TermId) -> Option<Linear> {
        let Node::Cmp { kind, a, b } = pool.node(p) else {
            return None;
        };
        let fa = extract(pool, *a);
        let fb = extract(pool, *b);
        let diff = fa.sub(&fb);
        let signed_ok = matches!(
            kind,
            CmpKind::Eq | CmpKind::Ne | CmpKind::Slt | CmpKind::Sle | CmpKind::Sgt | CmpKind::Sge
        );
        if !signed_ok {
            // admit unsigned kinds only when provably non-negative operand forms
            if !(form_nonneg(pool, &fa) && form_nonneg(pool, &fb)) {
                return None;
            }
        }
        let kind = match kind {
            CmpKind::Ult => CmpKind::Slt,
            CmpKind::Ule => CmpKind::Sle,
            CmpKind::Ugt => CmpKind::Sgt,
            CmpKind::Uge => CmpKind::Sge,
            k => *k,
        };
        Some(canonicalize(&diff, kind))
    }

    /// Decide the truth of `p` under the recorded assumptions.
    pub fn check(&self, pool: &TermPool, p: TermId) -> Truth {
        if let Some(c) = pool.as_const(p) {
            return if c & 1 == 1 { Truth::True } else { Truth::False };
        }
        if let Some(&v) = self.opaque.get(&p) {
            return if v { Truth::True } else { Truth::False };
        }
        // not-of-opaque
        if let Node::Not { a, .. } = pool.node(p) {
            if let Some(&v) = self.opaque.get(a) {
                return if v { Truth::False } else { Truth::True };
            }
        }
        let Some(lin) = self.linearize(pool, p) else {
            return Truth::Unknown;
        };
        let Some(facts) = self.forms.get(&lin.key) else {
            return Truth::Unknown;
        };
        decide(facts, lin.kind, lin.rhs)
    }

    /// Record `p == v`. Returns `Err(Conflict)` when it contradicts the
    /// existing facts (the paper removes such flows).
    pub fn assume(&mut self, pool: &TermPool, p: TermId, v: bool) -> Result<(), Conflict> {
        match self.check(pool, p) {
            Truth::True if !v => return Err(Conflict),
            Truth::False if v => return Err(Conflict),
            _ => {}
        }
        if let Some(lin) = self.linearize(pool, p) {
            let facts = self.forms.entry(lin.key).or_default();
            apply(facts, lin.kind, lin.rhs, v)?;
            return Ok(());
        }
        // opaque fact — also strip one Not for normalization
        if let Node::Not { a, .. } = pool.node(p) {
            let a = *a;
            if self.opaque.get(&a) == Some(&v) {
                return Err(Conflict);
            }
            self.opaque.insert(a, !v);
            return Ok(());
        }
        if self.opaque.get(&p) == Some(&!v) {
            return Err(Conflict);
        }
        self.opaque.insert(p, v);
        Ok(())
    }

    /// Drop facts that mention any of the given atoms (store invalidation —
    /// same mechanism the paper uses for conflicting assumptions, §4.3).
    pub fn invalidate_atoms(&mut self, atoms: &[TermId]) {
        self.forms
            .retain(|k, _| !k.0.iter().any(|(t, _)| atoms.contains(t)));
        self.opaque.retain(|&t, _| !atoms.contains(&t));
    }

    pub fn fact_count(&self) -> usize {
        self.forms.len() + self.opaque.len()
    }

    /// Snapshot every recorded fact as plain data (the serialization hook
    /// for [`crate::sym::persist`]). Atom `TermId`s in the image are
    /// pool-relative; the codec spells them out as graph roots.
    pub fn export(&self) -> AssumptionsImage {
        AssumptionsImage {
            forms: self
                .forms
                .iter()
                .map(|(k, f)| FormImage {
                    atoms: k.0.clone(),
                    lo: f.lo,
                    hi: f.hi,
                    ne: f.ne.clone(),
                    nonneg: f.nonneg,
                })
                .collect(),
            opaque: self.opaque.iter().map(|(&t, &v)| (t, v)).collect(),
        }
    }

    /// Rebuild an assumption set from an image whose atom `TermId`s have
    /// already been relocated into the target pool.
    ///
    /// Relocation renumbers terms, which breaks both invariants of the
    /// canonical [`FormKey`]: atoms sorted by id, and the first (smallest)
    /// atom's coefficient non-negative. Each form is therefore
    /// re-canonicalized here — atoms re-sorted, and when the leading
    /// coefficient turned negative the whole form is negated (`g → -g`,
    /// so `lo/hi` swap signs and places and the `ne` set negates) — so a
    /// relocated fact set answers [`Assumptions::check`] exactly like the
    /// original.
    pub fn from_image(img: AssumptionsImage) -> Assumptions {
        let mut out = Assumptions::new();
        for mut f in img.forms {
            f.atoms.sort_by_key(|&(t, _)| t);
            let flip = f.atoms.first().map(|&(_, c)| c < 0).unwrap_or(false);
            let (lo, hi, ne) = if flip {
                for a in f.atoms.iter_mut() {
                    a.1 = -a.1;
                }
                (f.hi.map(|v| -v), f.lo.map(|v| -v), f.ne.iter().map(|v| -v).collect())
            } else {
                (f.lo, f.hi, f.ne)
            };
            out.forms.insert(
                FormKey(f.atoms),
                FormFacts {
                    lo,
                    hi,
                    ne,
                    nonneg: f.nonneg,
                },
            );
        }
        for (t, v) in img.opaque {
            out.opaque.insert(t, v);
        }
        out
    }
}

/// Serializable snapshot of one linear-form fact (see
/// [`Assumptions::export`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FormImage {
    /// `(atom, coefficient)` pairs of the canonical linear form.
    pub atoms: Vec<(TermId, i128)>,
    pub lo: Option<i128>,
    pub hi: Option<i128>,
    pub ne: Vec<i128>,
    pub nonneg: bool,
}

/// Serializable snapshot of a whole [`Assumptions`] set.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AssumptionsImage {
    pub forms: Vec<FormImage>,
    pub opaque: Vec<(TermId, bool)>,
}

fn decide(facts: &FormFacts, kind: CmpKind, rhs: i128) -> Truth {
    if let Some(v) = facts.fixed() {
        let b = match kind {
            CmpKind::Eq => v == rhs,
            CmpKind::Ne => v != rhs,
            CmpKind::Slt => v < rhs,
            CmpKind::Sle => v <= rhs,
            CmpKind::Sgt => v > rhs,
            CmpKind::Sge => v >= rhs,
            _ => return Truth::Unknown,
        };
        return if b { Truth::True } else { Truth::False };
    }
    let lo = facts.lo.or(if facts.nonneg { Some(0) } else { None });
    let hi = facts.hi;
    match kind {
        CmpKind::Eq => {
            if !facts.admits(rhs) {
                Truth::False
            } else {
                Truth::Unknown
            }
        }
        CmpKind::Ne => {
            if !facts.admits(rhs) {
                Truth::True
            } else if facts.ne.contains(&rhs) {
                Truth::True
            } else {
                Truth::Unknown
            }
        }
        CmpKind::Slt => match (lo, hi) {
            (_, Some(h)) if h < rhs => Truth::True,
            (Some(l), _) if l >= rhs => Truth::False,
            _ => Truth::Unknown,
        },
        CmpKind::Sle => match (lo, hi) {
            (_, Some(h)) if h <= rhs => Truth::True,
            (Some(l), _) if l > rhs => Truth::False,
            _ => Truth::Unknown,
        },
        CmpKind::Sgt => match (lo, hi) {
            (Some(l), _) if l > rhs => Truth::True,
            (_, Some(h)) if h <= rhs => Truth::False,
            _ => Truth::Unknown,
        },
        CmpKind::Sge => match (lo, hi) {
            (Some(l), _) if l >= rhs => Truth::True,
            (_, Some(h)) if h < rhs => Truth::False,
            _ => Truth::Unknown,
        },
        _ => Truth::Unknown,
    }
}

fn apply(facts: &mut FormFacts, kind: CmpKind, rhs: i128, v: bool) -> Result<(), Conflict> {
    // rewrite negated constraints into positive ones
    let (kind, rhs) = if v {
        (kind, rhs)
    } else {
        match kind {
            CmpKind::Eq => (CmpKind::Ne, rhs),
            CmpKind::Ne => (CmpKind::Eq, rhs),
            CmpKind::Slt => (CmpKind::Sge, rhs),
            CmpKind::Sle => (CmpKind::Sgt, rhs),
            CmpKind::Sgt => (CmpKind::Sle, rhs),
            CmpKind::Sge => (CmpKind::Slt, rhs),
            _ => return Ok(()),
        }
    };
    match kind {
        CmpKind::Eq => {
            if !facts.admits(rhs) {
                return Err(Conflict);
            }
            facts.lo = Some(rhs);
            facts.hi = Some(rhs);
        }
        CmpKind::Ne => {
            if facts.fixed() == Some(rhs) {
                return Err(Conflict);
            }
            if !facts.ne.contains(&rhs) {
                facts.ne.push(rhs);
            }
        }
        CmpKind::Slt => tighten_hi(facts, rhs - 1)?,
        CmpKind::Sle => tighten_hi(facts, rhs)?,
        CmpKind::Sgt => tighten_lo(facts, rhs + 1)?,
        CmpKind::Sge => tighten_lo(facts, rhs)?,
        _ => {}
    }
    Ok(())
}

fn tighten_hi(facts: &mut FormFacts, h: i128) -> Result<(), Conflict> {
    let nh = facts.hi.map_or(h, |old| old.min(h));
    if let Some(lo) = facts.lo {
        if lo > nh {
            return Err(Conflict);
        }
    }
    facts.hi = Some(nh);
    Ok(())
}

fn tighten_lo(facts: &mut FormFacts, l: i128) -> Result<(), Conflict> {
    let nl = facts.lo.map_or(l, |old| old.max(l));
    if let Some(hi) = facts.hi {
        if nl > hi {
            return Err(Conflict);
        }
    }
    facts.lo = Some(nl);
    Ok(())
}

// ---------------------------------------------------------------------------
// Shuffle-delta solving (paper §5.1)
// ---------------------------------------------------------------------------

/// Find the integer `N` with `A(tid + N) = B(tid)` and `-31 ≤ N ≤ 31`,
/// where `tid_atom` is the term the thread id was emulated as.
///
/// Writes both addresses as `stride·tid + rest`; the equation holds for all
/// tids iff the strides agree and `rest_B - rest_A` is a constant multiple
/// of the stride.
pub fn solve_delta(
    pool: &TermPool,
    a_addr: TermId,
    b_addr: TermId,
    tid_atom: TermId,
) -> Option<i64> {
    let (sa, ra) = split_on(pool, a_addr, tid_atom);
    let (sb, rb) = split_on(pool, b_addr, tid_atom);
    if sa == 0 || sa != sb {
        return None;
    }
    let d = rb.sub(&ra);
    if !d.is_constant() {
        return None;
    }
    if d.constant % sa != 0 {
        return None;
    }
    let n = d.constant / sa;
    if (-31..=31).contains(&n) {
        Some(n as i64)
    } else {
        None
    }
}

/// How a staged `.shared` store's value reaches a later load, lane-wise.
///
/// `solve_forward` relates a *store* address `S(tid)` to a *load* address
/// `L(tid)`: which thread's store wrote the byte each thread loads. This is
/// the store→load analogue of [`solve_delta`] and drives the dead-store
/// elimination pass (`shuffle::phase_liveness`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForwardRel {
    /// `L(t) = S(t + n)`: thread `t` loads the byte thread `t + n` stored.
    /// `n = 0` means every thread reads back its own store.
    Shift(i64),
    /// The load address is thread-invariant and equals `S(t)` for exactly
    /// one thread `t` (`0 ≤ t ≤ 31`): every loading thread reads the byte
    /// thread `t` stored.
    Broadcast(i64),
}

/// Relate a store address to a load address lane-wise: find how the value
/// staged by `store_addr` flows to `load_addr` across threads.
///
/// Writes both addresses as `stride·tid + rest`. Equal non-zero strides
/// with a constant, stride-divisible rest difference `d` give
/// `Shift(d / stride)` (bounded to ±31, one warp). A thread-invariant load
/// address over a strided store gives `Broadcast(d / stride)` when the
/// source lane lands in `0..=31`. Anything else — mismatched strides,
/// symbolic rest difference, out-of-warp distance — is `None`, which
/// callers must treat as "unknown ⇒ may interfere".
pub fn solve_forward(
    pool: &TermPool,
    store_addr: TermId,
    load_addr: TermId,
    tid_atom: TermId,
) -> Option<ForwardRel> {
    let (ss, rs) = split_on(pool, store_addr, tid_atom);
    let (sl, rl) = split_on(pool, load_addr, tid_atom);
    let d = rl.sub(&rs);
    if !d.is_constant() {
        return None;
    }
    if ss != 0 && ss == sl {
        if d.constant % ss != 0 {
            return None;
        }
        let n = d.constant / ss;
        if (-31..=31).contains(&n) {
            return Some(ForwardRel::Shift(n as i64));
        }
        return None;
    }
    if ss != 0 && sl == 0 {
        if d.constant % ss != 0 {
            return None;
        }
        let t = d.constant / ss;
        if (0..=31).contains(&t) {
            return Some(ForwardRel::Broadcast(t as i64));
        }
        return None;
    }
    None
}

/// Byte distance `B - A` when it is constant (used for overlap checks and
/// alias analysis). `None` when the difference is symbolic.
pub fn const_distance(pool: &TermPool, a_addr: TermId, b_addr: TermId) -> Option<i128> {
    let d = extract(pool, b_addr).sub(&extract(pool, a_addr));
    if d.is_constant() {
        Some(d.constant)
    } else {
        None
    }
}

/// May the `b_bytes` at `b_addr` overlap the `a_bytes` at `a_addr`?
/// Conservative: unknown distance ⇒ may alias.
pub fn may_alias(
    pool: &TermPool,
    a_addr: TermId,
    a_bytes: u64,
    b_addr: TermId,
    b_bytes: u64,
) -> bool {
    match const_distance(pool, a_addr, b_addr) {
        Some(d) => d > -(b_bytes as i128) && d < a_bytes as i128,
        None => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sym::term::{BvOp, TermPool};

    fn addr(p: &mut TermPool, base: TermId, idx: TermId, scale: u64, off: i64) -> TermId {
        let w = p.sext(idx, 64);
        let c = p.constant(scale, 64);
        let s = p.bin(BvOp::Mul, w, c);
        let t = p.bin(BvOp::Add, base, s);
        let o = p.constant(off as u64, 64);
        p.bin(BvOp::Add, t, o)
    }

    #[test]
    fn solves_jacobi_delta() {
        // paper example: w0(i-1,j+1) at rd31+12, w0(i-1,j-1) at rd31+4 → N = -2
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let a = addr(&mut p, base, tid, 4, 12);
        let b = addr(&mut p, base, tid, 4, 4);
        assert_eq!(solve_delta(&p, a, b, tid), Some(-2));
        // same address → N = 0
        assert_eq!(solve_delta(&p, a, a, tid), Some(0));
        // reverse direction → +2
        assert_eq!(solve_delta(&p, b, a, tid), Some(2));
    }

    #[test]
    fn rejects_mismatched_stride_or_nonconst() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let base2 = p.symbol("base2", 64);
        let a4 = addr(&mut p, base, tid, 4, 0);
        let a8 = addr(&mut p, base, tid, 8, 0);
        assert_eq!(solve_delta(&p, a4, a8, tid), None);
        let b = addr(&mut p, base2, tid, 4, 4);
        assert_eq!(solve_delta(&p, a4, b, tid), None); // different arrays
    }

    #[test]
    fn rejects_unaligned_and_distant() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let a = addr(&mut p, base, tid, 4, 0);
        let b2 = addr(&mut p, base, tid, 4, 2); // not a multiple of stride
        assert_eq!(solve_delta(&p, a, b2, tid), None);
        let b_far = addr(&mut p, base, tid, 4, 4 * 32); // N = 32 > 31
        assert_eq!(solve_delta(&p, a, b_far, tid), None);
    }

    #[test]
    fn delta_without_tid_stride_rejected() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let j = p.symbol("j", 32);
        let a = addr(&mut p, base, j, 4, 0); // address independent of tid
        let b = addr(&mut p, base, j, 4, 4);
        assert_eq!(solve_delta(&p, a, b, tid), None);
    }

    #[test]
    fn assumption_conflict_detected() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let z = p.constant(0, 32);
        let eq = p.cmp(CmpKind::Eq, x, z);
        let mut a = Assumptions::new();
        a.assume(&p, eq, true).unwrap();
        assert_eq!(a.check(&p, eq), Truth::True);
        assert_eq!(a.assume(&p, eq, false), Err(Conflict));
    }

    #[test]
    fn interval_implication() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let c100 = p.constant(100, 32);
        let c200 = p.constant(200, 32);
        let c50 = p.constant(50, 32);
        let lt100 = p.cmp(CmpKind::Slt, x, c100);
        let lt200 = p.cmp(CmpKind::Slt, x, c200);
        let lt50 = p.cmp(CmpKind::Slt, x, c50);
        let mut a = Assumptions::new();
        a.assume(&p, lt100, true).unwrap();
        assert_eq!(a.check(&p, lt200), Truth::True);
        assert_eq!(a.check(&p, lt50), Truth::Unknown);
        // x < 100 and x >= 100 conflict
        let ge100 = p.cmp(CmpKind::Sge, x, c100);
        assert_eq!(a.check(&p, ge100), Truth::False);
    }

    #[test]
    fn sign_normalized_keys_match() {
        // x < y recorded; query y > x must be True
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let y = p.symbol("y", 32);
        let xy = p.cmp(CmpKind::Slt, x, y);
        let yx = p.cmp(CmpKind::Sgt, y, x);
        let mut a = Assumptions::new();
        a.assume(&p, xy, true).unwrap();
        assert_eq!(a.check(&p, yx), Truth::True);
    }

    #[test]
    fn unsigned_on_possibly_negative_stays_unknown() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let c = p.constant(10, 32);
        let ult = p.cmp(CmpKind::Ult, x, c);
        let mut a = Assumptions::new();
        a.assume(&p, ult, true).unwrap();
        // a second, looser unsigned bound must NOT be decided (x may be "negative" i.e. huge)
        let c2 = p.constant(20, 32);
        let ult2 = p.cmp(CmpKind::Ult, x, c2);
        assert_eq!(a.check(&p, ult2), Truth::Unknown);
    }

    #[test]
    fn unsigned_on_zext_is_decided() {
        let mut p = TermPool::new();
        let x32 = p.symbol("x", 32);
        let x = p.zext(x32, 64);
        let c = p.constant(10, 64);
        let c2 = p.constant(20, 64);
        let ult = p.cmp(CmpKind::Ult, x, c);
        let ult2 = p.cmp(CmpKind::Ult, x, c2);
        let mut a = Assumptions::new();
        a.assume(&p, ult, true).unwrap();
        assert_eq!(a.check(&p, ult2), Truth::True);
    }

    #[test]
    fn opaque_predicates_roundtrip() {
        let mut p = TermPool::new();
        let q = p.symbol("q", 1);
        let mut a = Assumptions::new();
        assert_eq!(a.check(&p, q), Truth::Unknown);
        a.assume(&p, q, true).unwrap();
        assert_eq!(a.check(&p, q), Truth::True);
        let nq = p.not(q);
        assert_eq!(a.check(&p, nq), Truth::False);
        assert_eq!(a.assume(&p, nq, true), Err(Conflict));
    }

    #[test]
    fn invalidate_atoms_drops_facts() {
        let mut p = TermPool::new();
        let l = p.uf("load", vec![], 32);
        let z = p.constant(0, 32);
        let eq = p.cmp(CmpKind::Eq, l, z);
        let mut a = Assumptions::new();
        a.assume(&p, eq, true).unwrap();
        assert_eq!(a.check(&p, eq), Truth::True);
        a.invalidate_atoms(&[l]);
        assert_eq!(a.check(&p, eq), Truth::Unknown);
    }

    #[test]
    fn solve_forward_shift() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let st = addr(&mut p, base, tid, 4, 0);
        let ld0 = addr(&mut p, base, tid, 4, 0);
        let ld_up = addr(&mut p, base, tid, 4, 16);
        let ld_dn = addr(&mut p, base, tid, 4, -4);
        // same address: every thread reads back its own store
        assert_eq!(solve_forward(&p, st, ld0, tid), Some(ForwardRel::Shift(0)));
        // load 4 elements ahead: thread t reads thread t+4's store
        assert_eq!(solve_forward(&p, st, ld_up, tid), Some(ForwardRel::Shift(4)));
        // load 1 element behind: thread t reads thread t-1's store
        assert_eq!(solve_forward(&p, st, ld_dn, tid), Some(ForwardRel::Shift(-1)));
    }

    #[test]
    fn solve_forward_broadcast() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let st = addr(&mut p, base, tid, 4, 0);
        // thread-invariant load of element 0 → broadcast from thread 0
        let c0 = p.constant(0, 64);
        let ld0 = p.bin(BvOp::Add, base, c0);
        assert_eq!(
            solve_forward(&p, st, ld0, tid),
            Some(ForwardRel::Broadcast(0))
        );
        // element 5 → thread 5
        let c20 = p.constant(20, 64);
        let ld5 = p.bin(BvOp::Add, base, c20);
        assert_eq!(
            solve_forward(&p, st, ld5, tid),
            Some(ForwardRel::Broadcast(5))
        );
        // element 40 is outside the warp
        let c160 = p.constant(160, 64);
        let ld40 = p.bin(BvOp::Add, base, c160);
        assert_eq!(solve_forward(&p, st, ld40, tid), None);
    }

    #[test]
    fn solve_forward_rejects_unknowns() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let other = p.symbol("other", 64);
        let j = p.symbol("j", 32);
        let st = addr(&mut p, base, tid, 4, 0);
        // mismatched stride
        let ld8 = addr(&mut p, base, tid, 8, 0);
        assert_eq!(solve_forward(&p, st, ld8, tid), None);
        // symbolic rest difference (different base objects)
        let ldo = addr(&mut p, other, tid, 4, 0);
        assert_eq!(solve_forward(&p, st, ldo, tid), None);
        // data-dependent index: rest difference is symbolic
        let ldj = addr(&mut p, base, j, 4, 0);
        assert_eq!(solve_forward(&p, st, ldj, tid), None);
        // out-of-warp shift
        let ld_far = addr(&mut p, base, tid, 4, 4 * 32);
        assert_eq!(solve_forward(&p, st, ld_far, tid), None);
        // unaligned offset
        let ld_mis = addr(&mut p, base, tid, 4, 2);
        assert_eq!(solve_forward(&p, st, ld_mis, tid), None);
        // tid-invariant store never forwards
        let stj = addr(&mut p, base, j, 4, 0);
        let ld = addr(&mut p, base, tid, 4, 0);
        assert_eq!(solve_forward(&p, stj, ld, tid), None);
    }

    #[test]
    fn may_alias_logic() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let other = p.symbol("other", 64);
        let a = addr(&mut p, base, tid, 4, 0);
        let b = addr(&mut p, base, tid, 4, 4);
        assert!(!may_alias(&p, a, 4, b, 4)); // adjacent words
        assert!(may_alias(&p, a, 4, a, 4)); // same word
        assert!(may_alias(&p, a, 8, b, 4)); // 8-byte overlaps next word
        let c = addr(&mut p, other, tid, 4, 0);
        assert!(may_alias(&p, a, 4, c, 4)); // unknown distance
    }
}
