//! Symbolic bitvector engine: terms, affine normal forms, SMT-lite solver.
//!
//! Replaces the paper's Rosette + Z3 stack (see DESIGN.md substitution
//! table). `term` is the hash-consed concolic term arena, `affine` the
//! linear normal-form extraction, `solver` the assumption store and the
//! shuffle-delta procedure.

pub mod affine;
pub mod persist;
pub mod solver;
pub mod term;

pub use affine::{extract, split_on, Affine};
pub use persist::{
    decode_emulation, decode_partial_emulation, encode_emulation, encode_partial_emulation,
    PERSIST_VERSION,
};
pub use solver::{
    const_distance, may_alias, solve_delta, solve_forward, Assumptions, AssumptionsImage,
    Conflict, FormImage, ForwardRel, Truth,
};
pub use term::{eval, BvOp, CmpKind, Node, SessionInterner, SymId, TermId, TermPool, UfId};
