//! Hand-rolled CLI argument parsing (no clap in the offline crate set).

use std::collections::HashMap;

/// Parsed command line: subcommand, positional args, `--key value` /
/// `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                    && !Self::is_flag(key)
                {
                    out.options
                        .insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.command.is_empty() {
                out.command = a;
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Known boolean flags (never consume a value).
    fn is_flag(key: &str) -> bool {
        matches!(
            key,
            "help"
                | "report"
                | "list"
                | "quiet"
                | "force"
                | "stats"
                | "no-disk-cache"
                | "detect-races"
                | "shared"
                | "no-elim"
                | "verify"
                | "heal"
                | "test-faults"
                | "json"
        )
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got `{v}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("asm input.ptx output.ptx");
        assert_eq!(a.command, "asm");
        assert_eq!(a.positional, vec!["input.ptx", "output.ptx"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse("suite --arch Maxwell --max-delta=3 --report");
        assert_eq!(a.opt("arch"), Some("Maxwell"));
        assert_eq!(a.opt("max-delta"), Some("3"));
        assert!(a.flag("report"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn stats_is_a_bare_flag() {
        // `--stats` must not swallow the following positional
        let a = parse("suite --stats jacobi");
        assert!(a.flag("stats"));
        assert_eq!(a.positional, vec!["jacobi"]);
    }

    #[test]
    fn disk_cache_flags() {
        let a = parse("suite --no-disk-cache jacobi --cache-dir /tmp/x");
        assert!(a.flag("no-disk-cache"));
        assert_eq!(a.opt("cache-dir"), Some("/tmp/x"));
        assert_eq!(a.positional, vec!["jacobi"]);
    }

    #[test]
    fn no_elim_is_a_bare_flag() {
        // `--no-elim` must not swallow the following positional
        let a = parse("suite --no-elim tiledreduce");
        assert!(a.flag("no-elim"));
        assert_eq!(a.positional, vec!["tiledreduce"]);
    }

    #[test]
    fn sim_threads_takes_a_value() {
        let a = parse("suite jacobi --sim-threads 4 --stats");
        assert_eq!(a.opt_usize("sim-threads", 1).unwrap(), 4);
        assert!(a.flag("stats"));
        assert_eq!(a.positional, vec!["jacobi"]);
    }

    #[test]
    fn engine_takes_a_value() {
        // `--engine` consumes its value and leaves surrounding
        // positionals/flags intact (it is NOT in the bare-flag whitelist)
        let a = parse("suite jacobi --engine scalar --stats");
        assert_eq!(a.opt("engine"), Some("scalar"));
        assert!(a.flag("stats"));
        assert_eq!(a.positional, vec!["jacobi"]);
        let b = parse("suite --engine=superblock jacobi");
        assert_eq!(b.opt("engine"), Some("superblock"));
        assert_eq!(b.positional, vec!["jacobi"]);
    }

    #[test]
    fn store_verify_and_heal_are_bare_flags() {
        // `store --verify --heal` must not swallow a following path
        let a = parse("store --verify --heal --cache-dir /tmp/x");
        assert!(a.flag("verify"));
        assert!(a.flag("heal"));
        assert_eq!(a.opt("cache-dir"), Some("/tmp/x"));
    }

    #[test]
    fn serve_options_parse() {
        let a = parse("serve --deadline-ms 500 --test-faults --socket /tmp/s.sock");
        assert_eq!(a.opt_usize("deadline-ms", 0).unwrap(), 500);
        assert!(a.flag("test-faults"));
        assert_eq!(a.opt("socket"), Some("/tmp/s.sock"));
        // asm's --block takes a value
        let b = parse("asm in.ptx --block 32 --report");
        assert_eq!(b.opt_usize("block", 32).unwrap(), 32);
        assert!(b.flag("report"));
        assert_eq!(b.positional, vec!["in.ptx"]);
    }

    #[test]
    fn serve_concurrency_options_parse() {
        // `--serve-threads` and `--trace-sample` take values and leave
        // neighbors intact
        let a = parse("serve --serve-threads 4 --trace-sample 16 --socket /tmp/s.sock");
        assert_eq!(a.opt_usize("serve-threads", 1).unwrap(), 4);
        assert_eq!(a.opt_usize("trace-sample", 0).unwrap(), 16);
        assert_eq!(a.opt("socket"), Some("/tmp/s.sock"));
    }

    #[test]
    fn json_is_a_bare_flag() {
        // `metrics --json` must not swallow a following cache-dir path
        let a = parse("metrics --json --cache-dir /tmp/x");
        assert!(a.flag("json"));
        assert_eq!(a.opt("cache-dir"), Some("/tmp/x"));
    }

    #[test]
    fn trace_out_takes_a_value() {
        let a = parse("suite --trace-out trace.json jacobi");
        assert_eq!(a.opt("trace-out"), Some("trace.json"));
        assert_eq!(a.positional, vec!["jacobi"]);
    }

    #[test]
    fn opt_usize_parses() {
        let a = parse("suite --threads 8");
        assert_eq!(a.opt_usize("threads", 1).unwrap(), 8);
        assert_eq!(a.opt_usize("missing", 3).unwrap(), 3);
        let bad = parse("suite --threads x");
        assert!(bad.opt_usize("threads", 1).is_err());
    }
}
