//! PTXASW — symbolic emulator + shuffle synthesis for NVIDIA PTX.
//!
//! Reproduction of Matsumura et al., *A Symbolic Emulator for Shuffle
//! Synthesis on the NVIDIA PTX Code* (CC '23). See DESIGN.md for the system
//! inventory and the substitutions made for the GPU-less testbed.
#![forbid(unsafe_code)]
#![deny(missing_debug_implementations)]
pub mod cli;
pub mod coordinator;
pub mod emu;
pub mod obs;
pub mod perf;
pub mod pipeline;
pub mod ptx;
pub mod runtime;
pub mod shuffle;
pub mod sim;
pub mod suite;
pub mod sym;
pub mod util;
