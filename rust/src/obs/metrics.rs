//! Unified metrics registry: named monotonic counters + fixed-bucket
//! latency histograms.
//!
//! The pipeline keeps its specialized stat structs (`CacheSnapshot`,
//! `DiskSnapshot`, `ServeStats`, `SimStats`, `StageTimings`) — dozens of
//! tests pin their exact semantics. [`MetricsSnapshot`] is the *unifying
//! view*: a flat, versioned list of `(stable dotted name, value)` pairs
//! collected from those structs at read time, so every surface (`--stats`,
//! the serve `metrics` request, `ptxasw metrics --json`) reports the same
//! names with the same meanings.
//!
//! Histograms use one fixed geometric bucket layout
//! ([`HIST_BOUNDS_NANOS`], ~4x steps from 1µs to 16s plus an overflow
//! bucket) so snapshots from different sources merge bucket-by-bucket.
//! Recording is lock-free (relaxed atomic adds); snapshots are
//! monotone-consistent, not cross-bucket-atomic — fine for telemetry.

use crate::util::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Version stamp carried by every [`MetricsSnapshot`] (bump when a stable
/// name changes meaning or disappears; adding names is compatible).
pub const METRICS_VERSION: u32 = 1;

/// Bucket count: [`HIST_BOUNDS_NANOS`] upper bounds + one overflow bucket.
pub const HIST_BUCKETS: usize = 14;

/// Inclusive upper bounds (nanoseconds) of the first 13 buckets: ~4x
/// geometric from 1µs to 16s. Observations above the last bound land in
/// the overflow bucket.
pub const HIST_BOUNDS_NANOS: [u64; HIST_BUCKETS - 1] = [
    1_000,
    4_000,
    16_000,
    64_000,
    256_000,
    1_000_000,
    4_000_000,
    16_000_000,
    64_000_000,
    256_000_000,
    1_000_000_000,
    4_000_000_000,
    16_000_000_000,
];

/// Index of the bucket an observation of `nanos` falls into.
fn bucket_index(nanos: u64) -> usize {
    HIST_BOUNDS_NANOS
        .iter()
        .position(|&b| nanos <= b)
        .unwrap_or(HIST_BUCKETS - 1)
}

/// Live fixed-bucket latency histogram (lock-free recording).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_nanos: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation.
    pub fn observe(&self, d: Duration) {
        let nanos = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        self.buckets[bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Fold a frozen snapshot's counts into this live histogram (serve
    /// workers merge their request-latency counts into the parent).
    pub fn absorb(&self, s: &HistSnapshot) {
        for (b, v) in self.buckets.iter().zip(s.buckets.iter()) {
            b.fetch_add(*v, Ordering::Relaxed);
        }
        self.sum_nanos.fetch_add(s.sum_nanos, Ordering::Relaxed);
    }

    /// A point-in-time copy of the counts.
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets = std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed));
        let mut s = HistSnapshot {
            buckets,
            count: 0,
            sum_nanos: self.sum_nanos.load(Ordering::Relaxed),
        };
        s.count = s.buckets.iter().sum();
        s
    }
}

/// Frozen histogram counts (the mergeable, serializable form).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket observation counts, [`HIST_BOUNDS_NANOS`] layout.
    pub buckets: [u64; HIST_BUCKETS],
    /// Total observations (sum of `buckets`).
    pub count: u64,
    /// Sum of all observed durations, nanoseconds (saturating).
    pub sum_nanos: u64,
}

impl HistSnapshot {
    pub fn mean_nanos(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_nanos / self.count
        }
    }

    /// Upper bound (nanoseconds) of the bucket containing the `q`-quantile
    /// observation (0.0..=1.0). Returns 0 for an empty histogram and
    /// `u64::MAX` when the quantile lands in the overflow bucket — it is a
    /// bucket *bound*, not an interpolated value.
    pub fn quantile_bound_nanos(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return HIST_BOUNDS_NANOS.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// Bucket-wise sum of two snapshots (same fixed layout).
    pub fn merged(&self, other: &HistSnapshot) -> HistSnapshot {
        let mut out = *self;
        for (b, o) in out.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        out.count += other.count;
        out.sum_nanos = out.sum_nanos.saturating_add(other.sum_nanos);
        out
    }
}

/// The unified, versioned metrics view: ordered `(stable name, value)`
/// lists, collected from the pipeline's stat structs at read time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub version: u32,
    pub counters: Vec<(String, u64)>,
    pub histograms: Vec<(String, HistSnapshot)>,
}

impl MetricsSnapshot {
    pub fn new() -> MetricsSnapshot {
        MetricsSnapshot {
            version: METRICS_VERSION,
            counters: Vec::new(),
            histograms: Vec::new(),
        }
    }

    /// Append a named monotonic counter.
    pub fn counter(&mut self, name: impl Into<String>, value: u64) {
        self.counters.push((name.into(), value));
    }

    /// Append a named latency histogram.
    pub fn histogram(&mut self, name: impl Into<String>, h: HistSnapshot) {
        self.histograms.push((name.into(), h));
    }

    /// Look up a counter by its stable name.
    pub fn get(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }

    /// Look up a histogram by its stable name.
    pub fn get_hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.histograms
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, h)| h)
    }

    /// Render as the machine-readable JSON document served by the `metrics`
    /// request and `ptxasw metrics --json`.
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .iter()
            .map(|(n, v)| (n.clone(), Json::num(*v as f64)))
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(n, h)| {
                let buckets = h.buckets.iter().map(|&c| Json::num(c as f64)).collect();
                (
                    n.clone(),
                    Json::Obj(vec![
                        ("count".to_string(), Json::num(h.count as f64)),
                        ("sum_nanos".to_string(), Json::num(h.sum_nanos as f64)),
                        ("mean_nanos".to_string(), Json::num(h.mean_nanos() as f64)),
                        ("buckets".to_string(), Json::Arr(buckets)),
                    ]),
                )
            })
            .collect();
        let bounds = HIST_BOUNDS_NANOS
            .iter()
            .map(|&b| Json::num(b as f64))
            .collect();
        Json::Obj(vec![
            (
                "metrics_version".to_string(),
                Json::num(self.version as f64),
            ),
            ("bucket_bounds_nanos".to_string(), Json::Arr(bounds)),
            ("counters".to_string(), Json::Obj(counters)),
            ("histograms".to_string(), Json::Obj(histograms)),
        ])
    }

    /// Render as the human table appended to `--stats` output.
    pub fn render_table(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "metrics (v{})", self.version);
        let width = self
            .counters
            .iter()
            .map(|(n, _)| n.len())
            .max()
            .unwrap_or(0)
            .max(8);
        for (name, v) in &self.counters {
            let _ = writeln!(out, "  {name:<width$}  {v}");
        }
        if !self.histograms.is_empty() {
            let _ = writeln!(out, "  latency histograms (count / mean / p50 / p99)");
            for (name, h) in &self.histograms {
                let _ = writeln!(
                    out,
                    "  {name:<width$}  {} / {} / {} / {}",
                    h.count,
                    fmt_nanos(h.mean_nanos()),
                    fmt_nanos(h.quantile_bound_nanos(0.5)),
                    fmt_nanos(h.quantile_bound_nanos(0.99)),
                );
            }
        }
        out
    }
}

/// Human-scale duration formatting for the metrics table; `u64::MAX`
/// marks the overflow bucket.
fn fmt_nanos(nanos: u64) -> String {
    if nanos == u64::MAX {
        return ">16s".to_string();
    }
    if nanos >= 1_000_000_000 {
        format!("{:.2}s", nanos as f64 / 1e9)
    } else if nanos >= 1_000_000 {
        format!("{:.2}ms", nanos as f64 / 1e6)
    } else if nanos >= 1_000 {
        format!("{:.2}us", nanos as f64 / 1e3)
    } else {
        format!("{nanos}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_matches_bounds() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1_000), 0);
        assert_eq!(bucket_index(1_001), 1);
        assert_eq!(bucket_index(16_000_000_000), HIST_BUCKETS - 2);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn observe_and_snapshot() {
        let h = Histogram::new();
        h.observe(Duration::from_micros(1)); // bucket 0
        h.observe(Duration::from_micros(2)); // bucket 1
        h.observe(Duration::from_secs(20)); // overflow bucket
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[HIST_BUCKETS - 1], 1);
        assert_eq!(s.sum_nanos, 1_000 + 2_000 + 20_000_000_000);
    }

    #[test]
    fn quantile_bounds() {
        let empty = HistSnapshot::default();
        assert_eq!(empty.quantile_bound_nanos(0.5), 0);

        let h = Histogram::new();
        for _ in 0..99 {
            h.observe(Duration::from_nanos(500)); // bucket 0: <= 1µs
        }
        h.observe(Duration::from_secs(20)); // overflow
        let s = h.snapshot();
        assert_eq!(s.quantile_bound_nanos(0.5), 1_000);
        assert_eq!(s.quantile_bound_nanos(0.99), 1_000);
        assert_eq!(s.quantile_bound_nanos(1.0), u64::MAX);
    }

    #[test]
    fn merged_sums_bucketwise() {
        let a = Histogram::new();
        a.observe(Duration::from_micros(1));
        let b = Histogram::new();
        b.observe(Duration::from_micros(1));
        b.observe(Duration::from_millis(2));
        let m = a.snapshot().merged(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.buckets[0], 2);
        assert_eq!(m.sum_nanos, 1_000 + 1_000 + 2_000_000);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let mut m = MetricsSnapshot::new();
        m.counter("cache.emulate.hits", 3);
        m.counter("serve.requests", 10);
        let h = Histogram::new();
        h.observe(Duration::from_micros(50));
        m.histogram("stage.emulate.latency", h.snapshot());

        let doc = Json::parse(&m.to_json().render()).expect("valid JSON");
        assert_eq!(
            doc.get("metrics_version").and_then(Json::as_u64),
            Some(u64::from(METRICS_VERSION))
        );
        let counters = doc.get("counters").unwrap();
        assert_eq!(
            counters.get("cache.emulate.hits").and_then(Json::as_u64),
            Some(3)
        );
        let hist = doc
            .get("histograms")
            .and_then(|h| h.get("stage.emulate.latency"))
            .unwrap();
        assert_eq!(hist.get("count").and_then(Json::as_u64), Some(1));
        assert_eq!(
            hist.get("buckets").and_then(Json::as_arr).map(Vec::len),
            Some(HIST_BUCKETS)
        );
        let bounds = doc.get("bucket_bounds_nanos").and_then(Json::as_arr).unwrap();
        assert_eq!(bounds.len(), HIST_BUCKETS - 1);
    }

    #[test]
    fn lookups_and_table() {
        let mut m = MetricsSnapshot::new();
        m.counter("a.b", 1);
        let h = Histogram::new();
        h.observe(Duration::from_secs(20));
        m.histogram("a.lat", h.snapshot());
        assert_eq!(m.get("a.b"), Some(1));
        assert_eq!(m.get("missing"), None);
        assert_eq!(m.get_hist("a.lat").map(|h| h.count), Some(1));
        let table = m.render_table();
        assert!(table.contains("a.b"));
        assert!(table.contains(">16s"), "overflow bucket prints >16s: {table}");
    }
}
