//! Ring-buffered span tracer with Chrome trace-event export.
//!
//! The [`Tracer`] records typed events — complete spans (`X`) with
//! microsecond timestamps and durations, and instants (`i`) — into a
//! bounded ring. The design goals, in order:
//!
//! 1. **A disabled tracer costs one relaxed atomic load per span.**
//!    [`Tracer::begin`] returns `SpanStart(None)` without touching the
//!    clock, [`Tracer::span`] early-returns on it, and argument closures
//!    are `FnOnce` thunks that are never invoked while disabled. The
//!    `simbench`/`servebench` CI gates pin this.
//! 2. **Tracing never changes results.** The tracer only observes: all
//!    state lives behind its own mutex and atomics, and nothing in the
//!    pipeline reads it back. The traced-vs-untraced differential in
//!    `tests/integration_obs.rs` pins bit-identical artifacts.
//! 3. **Bounded memory.** The ring holds [`DEFAULT_CAPACITY`] events;
//!    overflow drops the *oldest* event and counts it in
//!    [`Tracer::dropped`], which the Chrome export reports.
//!
//! Export is the Chrome trace-event JSON format (`{"traceEvents": [...]}`),
//! loadable at `ui.perfetto.dev` or `chrome://tracing`, rendered through
//! the zero-dep [`Json`] codec.

use crate::util::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default ring capacity (events). A full suite run emits a few hundred
/// spans; serve sessions recycle the ring per request via [`Tracer::mark`].
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Chrome trace-event phase of a recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    /// A complete span (`"ph": "X"`) with a start timestamp and duration.
    Complete,
    /// A zero-duration instant (`"ph": "i"`, thread-scoped).
    Instant,
}

/// A typed span argument value, rendered into the event's `args` object.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgVal {
    U64(u64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl ArgVal {
    fn to_json(&self) -> Json {
        match self {
            // u64 counters can exceed f64's exact-integer range in
            // pathological cases; the codec's `as_u64` guards reads.
            ArgVal::U64(n) => Json::num(*n as f64),
            ArgVal::F64(x) => Json::num(*x),
            ArgVal::Bool(b) => Json::Bool(*b),
            ArgVal::Str(s) => Json::str(s.clone()),
        }
    }
}

/// One recorded event. Names and categories are `&'static str` so that
/// recording allocates only for argument payloads.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Event name, e.g. `"stage.emulate"` (see the README span taxonomy).
    pub name: &'static str,
    /// Category, e.g. `"stage"`, `"store"`, `"serve"`.
    pub cat: &'static str,
    pub phase: TracePhase,
    /// Microseconds since the tracer's epoch.
    pub ts_micros: u64,
    /// Span duration in microseconds (0 for instants).
    pub dur_micros: u64,
    /// Stable per-thread id (see [`thread_tid`]).
    pub tid: u64,
    /// Logical session id, rendered as the Chrome `pid`: concurrent serve
    /// workers record into child tracers with distinct ids, so a merged
    /// export keeps every session's spans on its own process lane.
    pub session: u64,
    pub args: Vec<(&'static str, ArgVal)>,
}

impl TraceEvent {
    /// Render as one Chrome trace-event object.
    pub fn to_json(&self) -> Json {
        let mut kvs = vec![
            ("name".to_string(), Json::str(self.name)),
            ("cat".to_string(), Json::str(self.cat)),
            (
                "ph".to_string(),
                Json::str(match self.phase {
                    TracePhase::Complete => "X",
                    TracePhase::Instant => "i",
                }),
            ),
            ("ts".to_string(), Json::num(self.ts_micros as f64)),
        ];
        match self.phase {
            TracePhase::Complete => {
                kvs.push(("dur".to_string(), Json::num(self.dur_micros as f64)));
            }
            TracePhase::Instant => {
                // thread-scoped instant: renders as a tick, not a global line
                kvs.push(("s".to_string(), Json::str("t")));
            }
        }
        kvs.push(("pid".to_string(), Json::num(self.session as f64)));
        kvs.push(("tid".to_string(), Json::num(self.tid as f64)));
        if !self.args.is_empty() {
            let args = self
                .args
                .iter()
                .map(|(k, v)| ((*k).to_string(), v.to_json()))
                .collect();
            kvs.push(("args".to_string(), Json::Obj(args)));
        }
        Json::Obj(kvs)
    }
}

/// Opaque token from [`Tracer::begin`]: `None` while the tracer is
/// disabled, so no span ever reads the clock for free.
#[derive(Debug, Clone, Copy)]
#[must_use = "pass the start token back to Tracer::span to record the span"]
pub struct SpanStart(Option<Instant>);

/// Bounded event ring with a monotone base counter, so consumers can
/// address events by global sequence number across overflow.
#[derive(Debug)]
struct Ring {
    cap: usize,
    buf: VecDeque<TraceEvent>,
    /// Global sequence number of `buf[0]`.
    base: u64,
}

/// Lock-cheap span recorder. See the module docs for the contract.
#[derive(Debug)]
pub struct Tracer {
    on: AtomicBool,
    epoch: Instant,
    dropped: AtomicU64,
    /// Stamped into every event (the Chrome `pid`). `1` by default; serve
    /// workers get distinct ids via [`Tracer::child`].
    session: AtomicU64,
    ring: Mutex<Ring>,
}

impl Tracer {
    fn with_state(enabled: bool, cap: usize) -> Tracer {
        Tracer {
            on: AtomicBool::new(enabled),
            epoch: Instant::now(),
            dropped: AtomicU64::new(0),
            session: AtomicU64::new(1),
            ring: Mutex::new(Ring {
                cap: cap.max(1),
                buf: VecDeque::new(),
                base: 0,
            }),
        }
    }

    /// A tracer that records nothing until [`Tracer::set_enabled`] flips it.
    pub fn disabled() -> Tracer {
        Tracer::with_state(false, DEFAULT_CAPACITY)
    }

    /// A recording tracer with the default ring capacity.
    pub fn enabled() -> Tracer {
        Tracer::with_state(true, DEFAULT_CAPACITY)
    }

    /// A recording tracer with an explicit ring capacity (min 1).
    pub fn with_capacity(cap: usize) -> Tracer {
        Tracer::with_state(true, cap)
    }

    pub fn is_enabled(&self) -> bool {
        self.on.load(Ordering::Relaxed)
    }

    /// Flip recording at runtime (serve mode enables per `"trace": true`
    /// request without rebuilding the pipeline).
    pub fn set_enabled(&self, enabled: bool) {
        self.on.store(enabled, Ordering::Relaxed);
    }

    /// Start a span. The entire disabled-path cost is one relaxed load.
    pub fn begin(&self) -> SpanStart {
        if self.is_enabled() {
            SpanStart(Some(Instant::now()))
        } else {
            SpanStart(None)
        }
    }

    /// Record a complete span started at `start`. `args` is evaluated only
    /// if the span is actually recorded. A span begun while enabled is
    /// still recorded if the tracer was disabled in between — the start
    /// token, not the current flag, is the record/skip decision, so serve
    /// request spans survive their own per-request disable.
    pub fn span(
        &self,
        cat: &'static str,
        name: &'static str,
        start: SpanStart,
        args: impl FnOnce() -> Vec<(&'static str, ArgVal)>,
    ) {
        let Some(t0) = start.0 else { return };
        // `duration_since` saturates to zero if the epoch races ahead.
        let ts_micros = t0.duration_since(self.epoch).as_micros() as u64;
        let dur_micros = t0.elapsed().as_micros() as u64;
        self.push(TraceEvent {
            name,
            cat,
            phase: TracePhase::Complete,
            ts_micros,
            dur_micros,
            tid: thread_tid(),
            session: self.session.load(Ordering::Relaxed),
            args: args(),
        });
    }

    /// Record a zero-duration instant. `args` is evaluated only while
    /// enabled.
    pub fn instant(
        &self,
        cat: &'static str,
        name: &'static str,
        args: impl FnOnce() -> Vec<(&'static str, ArgVal)>,
    ) {
        if !self.is_enabled() {
            return;
        }
        let ts_micros = self.epoch.elapsed().as_micros() as u64;
        self.push(TraceEvent {
            name,
            cat,
            phase: TracePhase::Instant,
            ts_micros,
            dur_micros: 0,
            tid: thread_tid(),
            session: self.session.load(Ordering::Relaxed),
            args: args(),
        });
    }

    /// This tracer's logical session id (the Chrome `pid` of its events).
    pub fn session(&self) -> u64 {
        self.session.load(Ordering::Relaxed)
    }

    /// Re-label future events with a session id.
    pub fn set_session(&self, id: u64) {
        self.session.store(id, Ordering::Relaxed);
    }

    /// A tracer for one concurrent worker: its own ring, a distinct
    /// session id, the parent's enabled state and capacity — and the
    /// parent's *epoch*, so a merged export ([`Tracer::absorb`]) puts
    /// every session on one aligned timeline.
    pub fn child(&self, session: u64) -> Tracer {
        let cap = self.ring.lock().unwrap().cap;
        let t = Tracer::with_state(self.is_enabled(), cap);
        Tracer {
            epoch: self.epoch,
            session: AtomicU64::new(session),
            ..t
        }
    }

    /// Append another tracer's buffered events into this ring (concurrent
    /// serve merges worker tracers into the parent before `--trace-out`
    /// export). Events keep their own session ids; timestamps align when
    /// the other tracer came from [`Tracer::child`].
    pub fn absorb(&self, other: &Tracer) {
        for ev in other.events() {
            self.push(ev);
        }
        self.dropped.fetch_add(other.dropped(), Ordering::Relaxed);
    }

    fn push(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() >= ring.cap {
            ring.buf.pop_front();
            ring.base += 1;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.buf.push_back(ev);
    }

    /// Current global sequence watermark: events recorded after this call
    /// have sequence numbers `>= mark()`. Feed back to
    /// [`Tracer::events_since`] to extract a request's events.
    pub fn mark(&self) -> u64 {
        let ring = self.ring.lock().unwrap();
        ring.base + ring.buf.len() as u64
    }

    /// Events with global sequence `>= mark`, oldest first. Events evicted
    /// by ring overflow are simply absent.
    pub fn events_since(&self, mark: u64) -> Vec<TraceEvent> {
        let ring = self.ring.lock().unwrap();
        let skip = mark.saturating_sub(ring.base) as usize;
        ring.buf.iter().skip(skip).cloned().collect()
    }

    /// All buffered events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events_since(0)
    }

    /// Number of events currently buffered.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events lost to ring overflow since creation/`clear`.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Drop all buffered events and reset the drop counter. The global
    /// sequence keeps advancing (marks stay valid).
    pub fn clear(&self) {
        let mut ring = self.ring.lock().unwrap();
        let n = ring.buf.len() as u64;
        ring.base += n;
        ring.buf.clear();
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// Render everything buffered as a Chrome trace-event JSON document
    /// (Perfetto-loadable).
    pub fn export_chrome(&self) -> Json {
        let events = self.events().iter().map(TraceEvent::to_json).collect();
        Json::Obj(vec![
            ("traceEvents".to_string(), Json::Arr(events)),
            ("displayTimeUnit".to_string(), Json::str("ms")),
            (
                "otherData".to_string(),
                Json::Obj(vec![
                    ("tool".to_string(), Json::str("ptxasw")),
                    (
                        "dropped_events".to_string(),
                        Json::num(self.dropped() as f64),
                    ),
                ]),
            ),
        ])
    }
}

/// Stable small integer id for the calling thread. Chrome trace `tid`s
/// only need to be consistent within one export; a process-wide counter
/// handed out on first use per thread is cheap and deterministic enough.
pub fn thread_tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing_and_skips_arg_closures() {
        let t = Tracer::disabled();
        let mut evaluated = false;
        let s = t.begin();
        t.span("x", "x.span", s, || {
            evaluated = true;
            vec![]
        });
        t.instant("x", "x.instant", || {
            evaluated = true;
            vec![]
        });
        assert!(!evaluated, "arg closures must not run while disabled");
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn enabled_records_spans_and_instants() {
        let t = Tracer::enabled();
        let s = t.begin();
        t.span("stage", "stage.parse", s, || {
            vec![("key", ArgVal::Str("abc".into()))]
        });
        t.instant("store", "store.load", || vec![("outcome", ArgVal::U64(1))]);
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "stage.parse");
        assert_eq!(evs[0].phase, TracePhase::Complete);
        assert_eq!(evs[1].phase, TracePhase::Instant);
        assert!(evs[1].ts_micros >= evs[0].ts_micros);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let t = Tracer::with_capacity(4);
        for _ in 0..10 {
            t.instant("x", "x.tick", Vec::new);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        // the survivors are the newest four: global sequence 6..10
        assert_eq!(t.mark(), 10);
        assert_eq!(t.events_since(6).len(), 4);
        assert_eq!(t.events_since(9).len(), 1);
    }

    #[test]
    fn mark_and_events_since_slice_per_request() {
        let t = Tracer::enabled();
        t.instant("x", "x.before", Vec::new);
        let m = t.mark();
        t.instant("x", "x.after", Vec::new);
        let evs = t.events_since(m);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "x.after");
        t.clear();
        assert!(t.is_empty());
        assert!(t.mark() >= m, "marks survive clear");
    }

    #[test]
    fn chrome_export_round_trips_through_the_codec() {
        let t = Tracer::enabled();
        let s = t.begin();
        t.span("stage", "stage.emulate", s, || {
            vec![("flows", ArgVal::U64(7)), ("ok", ArgVal::Bool(true))]
        });
        t.instant("sim", "sim.engine", || {
            vec![("fallback", ArgVal::Str("none".into()))]
        });
        let doc = Json::parse(&t.export_chrome().render()).expect("valid JSON");
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), 2);
        let x = &evs[0];
        assert_eq!(x.get("ph").and_then(Json::as_str), Some("X"));
        assert!(x.get("dur").is_some(), "complete events carry a duration");
        assert_eq!(
            x.get("args").and_then(|a| a.get("flows")).and_then(Json::as_u64),
            Some(7)
        );
        let i = &evs[1];
        assert_eq!(i.get("ph").and_then(Json::as_str), Some("i"));
        assert_eq!(i.get("s").and_then(Json::as_str), Some("t"));
        assert!(i.get("dur").is_none(), "instants carry no duration");
    }

    #[test]
    fn set_enabled_flips_recording_at_runtime() {
        let t = Tracer::disabled();
        t.instant("x", "x.off", Vec::new);
        t.set_enabled(true);
        t.instant("x", "x.on", Vec::new);
        t.set_enabled(false);
        t.instant("x", "x.off2", Vec::new);
        let evs = t.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "x.on");
    }

    #[test]
    fn session_ids_stamp_events_and_children_merge_cleanly() {
        let t = Tracer::enabled();
        t.instant("x", "x.parent", Vec::new);
        let c = t.child(7);
        assert!(c.is_enabled(), "children inherit the enabled state");
        assert_eq!(c.session(), 7);
        c.instant("x", "x.child", Vec::new);
        t.absorb(&c);
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].session, 1);
        assert_eq!(evs[1].session, 7);
        // the export keeps the lanes apart via pid and stays codec-valid
        let doc = Json::parse(&t.export_chrome().render()).unwrap();
        let arr = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].get("pid").and_then(Json::as_u64), Some(1));
        assert_eq!(arr[1].get("pid").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn span_begun_while_enabled_survives_disable() {
        let t = Tracer::enabled();
        let s = t.begin();
        t.set_enabled(false);
        t.span("serve", "serve.request", s, Vec::new);
        assert_eq!(t.len(), 1, "the start token decides, not the flag");
    }
}
