//! Observability: structured span tracing + the unified metrics registry.
//!
//! Two zero-dependency halves:
//!
//! - [`trace`] — a lock-cheap, ring-buffered [`Tracer`] recording typed
//!   spans and instants across every pipeline layer (stage execution,
//!   artifact cache provenance, emulator budgets, simulator engine
//!   selection, elimination verdicts, store ops, serve requests),
//!   exportable as Chrome trace-event JSON (Perfetto-loadable) via
//!   `--trace-out` or per-request `"trace": true` in serve mode.
//! - [`metrics`] — named monotonic counters + fixed-bucket latency
//!   histograms folding the pipeline's specialized stat structs into one
//!   versioned [`MetricsSnapshot`], surfaced by `--stats`, the serve
//!   `metrics` request, and `ptxasw metrics --json`.
//!
//! Contract: a *disabled* tracer costs one relaxed atomic load per span
//! (pinned by the `simbench`/`servebench` CI gates), and tracing —
//! enabled or not — never changes pipeline results (pinned by the
//! traced-vs-untraced differential in `tests/integration_obs.rs`).

pub mod metrics;
pub mod trace;

pub use metrics::{
    HistSnapshot, Histogram, MetricsSnapshot, HIST_BOUNDS_NANOS, HIST_BUCKETS, METRICS_VERSION,
};
pub use trace::{thread_tid, ArgVal, SpanStart, TraceEvent, TracePhase, Tracer, DEFAULT_CAPACITY};
