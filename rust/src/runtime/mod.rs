//! PJRT runtime: load AOT-compiled HLO artifacts and execute them on CPU.
//!
//! The compile path (`make artifacts` → `python/compile/aot.py`) lowers the
//! L2 JAX graphs (which call the L1 Pallas kernels) to HLO **text** —
//! serialized `HloModuleProto`s from jax ≥ 0.5 carry 64-bit instruction
//! ids that xla_extension 0.5.1 rejects, while the text parser reassigns
//! ids cleanly. This module wraps the `xla` crate: client construction,
//! artifact discovery via `artifacts/manifest.txt`, compilation caching,
//! and typed f32 execution. Python never runs on this path.
//!
//! The `xla` crate is not part of the offline crate universe, so the
//! execution backend is gated behind the `xla` cargo feature. Without it,
//! manifest parsing and artifact discovery still work (enough for the CLI
//! `artifacts` listing and the unit tests); `load`/`run_f32` report a
//! clean [`RuntimeError::Backend`] error.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum RuntimeError {
    /// Artifact directory not found — run `make artifacts` first.
    NoArtifacts(PathBuf),
    /// Not in the manifest.
    UnknownArtifact(String),
    ArityMismatch {
        name: String,
        expect: usize,
        got: usize,
    },
    /// Execution-backend failure (XLA error, or backend compiled out).
    Backend(String),
    Io(std::io::Error),
    BadManifest(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::NoArtifacts(d) => write!(
                f,
                "artifact directory {} not found — run `make artifacts` first",
                d.display()
            ),
            RuntimeError::UnknownArtifact(n) => {
                write!(f, "unknown artifact `{n}` (not in manifest)")
            }
            RuntimeError::ArityMismatch { name, expect, got } => {
                write!(f, "artifact `{name}` expects {expect} inputs, got {got}")
            }
            RuntimeError::Backend(e) => write!(f, "xla error: {e}"),
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
            RuntimeError::BadManifest(l) => write!(f, "bad manifest line `{l}`"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> RuntimeError {
        RuntimeError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> RuntimeError {
        RuntimeError::Backend(e.to_string())
    }
}

/// Shape of one executable input (f32, dims in row-major order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    pub dims: Vec<usize>,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One loadable artifact (an L2 export).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub args: Vec<ArgSpec>,
    pub path: PathBuf,
}

/// PJRT CPU runtime with a compilation cache.
pub struct Runtime {
    backend: Backend,
    specs: HashMap<String, ArtifactSpec>,
}

// Manual impl: the xla backend's client/executable handles are foreign
// types without `Debug`.
impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("artifacts", &self.specs.len())
            .finish_non_exhaustive()
    }
}

impl Runtime {
    /// Open the runtime over an artifact directory (reads `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime, RuntimeError> {
        let dir = dir.as_ref();
        let manifest = dir.join("manifest.txt");
        if !manifest.exists() {
            return Err(RuntimeError::NoArtifacts(dir.to_path_buf()));
        }
        let mut specs = HashMap::new();
        for line in std::fs::read_to_string(&manifest)?.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            // "<name> f32 <d0,d1[;d0,d1...]>"
            let mut parts = line.split_whitespace();
            let (Some(name), Some(_dtype), Some(dims)) =
                (parts.next(), parts.next(), parts.next())
            else {
                return Err(RuntimeError::BadManifest(line.to_string()));
            };
            let args = dims
                .split(';')
                .map(|arg| {
                    arg.split(',')
                        .map(|d| d.parse::<usize>())
                        .collect::<Result<Vec<_>, _>>()
                        .map(|dims| ArgSpec { dims })
                })
                .collect::<Result<Vec<_>, _>>()
                .map_err(|_| RuntimeError::BadManifest(line.to_string()))?;
            specs.insert(
                name.to_string(),
                ArtifactSpec {
                    name: name.to_string(),
                    args,
                    path: dir.join(format!("{name}.hlo.txt")),
                },
            );
        }
        Ok(Runtime {
            backend: Backend::new()?,
            specs,
        })
    }

    /// Artifact names available in the manifest.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Compile (once) and cache an artifact.
    pub fn load(&mut self, name: &str) -> Result<(), RuntimeError> {
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        self.backend.load(spec)
    }

    /// Execute an artifact on f32 inputs; returns the flat f32 output.
    pub fn run_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>, RuntimeError> {
        self.load(name)?;
        let spec = &self.specs[name];
        if inputs.len() != spec.args.len() {
            return Err(RuntimeError::ArityMismatch {
                name: name.to_string(),
                expect: spec.args.len(),
                got: inputs.len(),
            });
        }
        for (arg, data) in spec.args.iter().zip(inputs) {
            assert_eq!(
                arg.elements(),
                data.len(),
                "{name}: input element count mismatch"
            );
        }
        self.backend.run_f32(&self.specs[name], inputs)
    }
}

#[cfg(feature = "xla")]
struct Backend {
    client: xla::PjRtClient,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl Backend {
    fn new() -> Result<Backend, RuntimeError> {
        Ok(Backend {
            client: xla::PjRtClient::cpu()?,
            compiled: HashMap::new(),
        })
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load(&mut self, spec: &ArtifactSpec) -> Result<(), RuntimeError> {
        if self.compiled.contains_key(&spec.name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(&spec.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(spec.name.clone(), exe);
        Ok(())
    }

    fn run_f32(
        &mut self,
        spec: &ArtifactSpec,
        inputs: &[&[f32]],
    ) -> Result<Vec<f32>, RuntimeError> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (arg, data) in spec.args.iter().zip(inputs) {
            let dims: Vec<i64> = arg.dims.iter().map(|&d| d as i64).collect();
            lits.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let exe = &self.compiled[&spec.name];
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Stub backend: manifest handling works, execution reports cleanly.
#[cfg(not(feature = "xla"))]
struct Backend;

#[cfg(not(feature = "xla"))]
impl Backend {
    fn new() -> Result<Backend, RuntimeError> {
        Ok(Backend)
    }

    fn platform(&self) -> String {
        "stub (built without the `xla` feature)".to_string()
    }

    fn load(&mut self, _spec: &ArtifactSpec) -> Result<(), RuntimeError> {
        Err(RuntimeError::Backend(
            "PJRT backend compiled out — rebuild with `--features xla`".to_string(),
        ))
    }

    fn run_f32(
        &mut self,
        _spec: &ArtifactSpec,
        _inputs: &[&[f32]],
    ) -> Result<Vec<f32>, RuntimeError> {
        Err(RuntimeError::Backend(
            "PJRT backend compiled out — rebuild with `--features xla`".to_string(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("ptxasw_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "alpha f32 16,96\nbeta f32 8,10,40;8,10,40\n",
        )
        .unwrap();
        let rt = Runtime::open(&dir).unwrap();
        assert_eq!(rt.names(), vec!["alpha", "beta"]);
        assert_eq!(rt.spec("alpha").unwrap().args[0].dims, vec![16, 96]);
        assert_eq!(rt.spec("beta").unwrap().args.len(), 2);
        assert_eq!(rt.spec("beta").unwrap().args[1].elements(), 3200);
    }

    #[test]
    fn missing_dir_is_clean_error() {
        match Runtime::open("/nonexistent/path/xyz") {
            Err(RuntimeError::NoArtifacts(_)) => {}
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("expected failure"),
        }
    }
}
