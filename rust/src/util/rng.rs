//! xorshift64* PRNG — deterministic, seedable, dependency-free.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng {
            state: if seed == 0 { 0xDEAD_BEEF_CAFE_F00D } else { seed },
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection sampling to kill modulo bias
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(1234);
        let mut buckets = [0u32; 8];
        for _ in 0..80_000 {
            buckets[r.below(8) as usize] += 1;
        }
        for b in buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b} out of range");
        }
    }
}
