//! Minimal JSON value, parser, and renderer — the wire format of the
//! `serve` mode's JSON-lines protocol (`pipeline/serve.rs`).
//!
//! Same philosophy as [`crate::util::codec`]: the crate is zero-dep, so the
//! codec is hand-rolled, and the parser is *total* — any byte sequence
//! yields `Some(Json)` or `None`, never a panic, and nesting depth is
//! bounded so an adversarial request line cannot blow the stack. The
//! subset is deliberate: numbers are `f64` (every integer the protocol
//! carries fits in 53 bits), no `\uXXXX` surrogate-pair pedantry beyond
//! BMP decoding, and object keys keep insertion order (responses render
//! deterministically, which the tests and `BENCH_8.json` rely on).

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Nesting deeper than this is refused, not recursed into.
const MAX_DEPTH: usize = 64;

impl Json {
    /// Parse one JSON document; trailing non-whitespace makes it `None`.
    pub fn parse(s: &str) -> Option<Json> {
        let b = s.as_bytes();
        let mut i = 0;
        let v = parse_value(b, &mut i, 0)?;
        skip_ws(b, &mut i);
        (i == b.len()).then_some(v)
    }

    /// Render to a compact JSON string (keys in insertion order).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => render_num(*n, out),
            Json::Str(s) => render_str(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    // -- accessors ---------------------------------------------------------

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numbers that are exactly representable non-negative integers.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

fn render_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn eat(b: &[u8], i: &mut usize, lit: &[u8]) -> Option<()> {
    if b.len() - *i >= lit.len() && &b[*i..*i + lit.len()] == lit {
        *i += lit.len();
        Some(())
    } else {
        None
    }
}

fn parse_value(b: &[u8], i: &mut usize, depth: usize) -> Option<Json> {
    if depth > MAX_DEPTH {
        return None;
    }
    skip_ws(b, i);
    match *b.get(*i)? {
        b'n' => eat(b, i, b"null").map(|_| Json::Null),
        b't' => eat(b, i, b"true").map(|_| Json::Bool(true)),
        b'f' => eat(b, i, b"false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, i).map(Json::Str),
        b'[' => {
            *i += 1;
            let mut xs = Vec::new();
            skip_ws(b, i);
            if b.get(*i) == Some(&b']') {
                *i += 1;
                return Some(Json::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, i, depth + 1)?);
                skip_ws(b, i);
                match *b.get(*i)? {
                    b',' => *i += 1,
                    b']' => {
                        *i += 1;
                        return Some(Json::Arr(xs));
                    }
                    _ => return None,
                }
            }
        }
        b'{' => {
            *i += 1;
            let mut kvs = Vec::new();
            skip_ws(b, i);
            if b.get(*i) == Some(&b'}') {
                *i += 1;
                return Some(Json::Obj(kvs));
            }
            loop {
                skip_ws(b, i);
                if *b.get(*i)? != b'"' {
                    return None;
                }
                let k = parse_string(b, i)?;
                skip_ws(b, i);
                if *b.get(*i)? != b':' {
                    return None;
                }
                *i += 1;
                kvs.push((k, parse_value(b, i, depth + 1)?));
                skip_ws(b, i);
                match *b.get(*i)? {
                    b',' => *i += 1,
                    b'}' => {
                        *i += 1;
                        return Some(Json::Obj(kvs));
                    }
                    _ => return None,
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, i),
        _ => None,
    }
}

fn parse_string(b: &[u8], i: &mut usize) -> Option<String> {
    // caller guarantees b[*i] == b'"'
    *i += 1;
    let mut out = String::new();
    loop {
        match *b.get(*i)? {
            b'"' => {
                *i += 1;
                return Some(out);
            }
            b'\\' => {
                *i += 1;
                match *b.get(*i)? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex = b.get(*i + 1..*i + 5)?;
                        let code =
                            u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
                        // BMP only; unpaired surrogates become U+FFFD
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *i += 4;
                    }
                    _ => return None,
                }
                *i += 1;
            }
            c if c < 0x20 => return None, // raw control char
            _ => {
                // copy one UTF-8 scalar; the input is a &str so bytes are valid
                let start = *i;
                *i += 1;
                while *i < b.len() && b[*i] & 0xC0 == 0x80 {
                    *i += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*i]).ok()?);
            }
        }
    }
}

fn parse_number(b: &[u8], i: &mut usize) -> Option<Json> {
    let start = *i;
    if b.get(*i) == Some(&b'-') {
        *i += 1;
    }
    while matches!(b.get(*i), Some(b'0'..=b'9')) {
        *i += 1;
    }
    if b.get(*i) == Some(&b'.') {
        *i += 1;
        while matches!(b.get(*i), Some(b'0'..=b'9')) {
            *i += 1;
        }
    }
    if matches!(b.get(*i), Some(b'e' | b'E')) {
        *i += 1;
        if matches!(b.get(*i), Some(b'+' | b'-')) {
            *i += 1;
        }
        while matches!(b.get(*i), Some(b'0'..=b'9')) {
            *i += 1;
        }
    }
    let s = std::str::from_utf8(&b[start..*i]).ok()?;
    let n: f64 = s.parse().ok()?;
    n.is_finite().then_some(Json::Num(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_a_request_shaped_document() {
        let src = r#"{"id":7,"cmd":"asm","ptx":"line1\nline2","block":32,"elim":true,"extra":[1,2.5,-3,null,false]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("cmd").unwrap().as_str(), Some("asm"));
        assert_eq!(v.get("ptx").unwrap().as_str(), Some("line1\nline2"));
        assert_eq!(v.get("elim").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("extra").unwrap().as_arr().unwrap().len(), 5);
        // render→parse is a fixpoint
        assert_eq!(Json::parse(&v.render()), Some(v));
    }

    #[test]
    fn escapes_survive_the_roundtrip() {
        let s = "quote\" backslash\\ newline\n tab\t unicode\u{1F600} ctrl\u{1}";
        let rendered = Json::str(s).render();
        assert_eq!(Json::parse(&rendered).unwrap().as_str(), Some(s));
        // \uXXXX decoding
        assert_eq!(
            Json::parse(r#""\u0041\u00e9""#).unwrap().as_str(),
            Some("Aé")
        );
    }

    #[test]
    fn garbage_is_refused_not_panicked() {
        for bad in [
            "", "{", "}", "[1,", "{\"a\"}", "{\"a\":}", "tru", "nul", "01x",
            "\"unterminated", "{\"a\":1}trailing", "[1 2]", "\"\\q\"", "nan",
            "1e999", "--1", "\u{7}",
        ] {
            assert_eq!(Json::parse(bad), None, "input {bad:?} must be refused");
        }
    }

    #[test]
    fn depth_is_bounded() {
        let deep = "[".repeat(1000) + &"]".repeat(1000);
        assert_eq!(Json::parse(&deep), None, "1000 levels exceeds MAX_DEPTH");
        let ok = "[".repeat(32) + &"]".repeat(32);
        assert!(Json::parse(&ok).is_some());
    }

    #[test]
    fn numbers_render_integers_without_exponent() {
        assert_eq!(Json::num(123u32).render(), "123");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::parse("123").unwrap().as_u64(), Some(123));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.25").unwrap().as_f64(), Some(1.25));
    }
}
