//! Little-endian, length-prefixed binary writer/reader — the primitives
//! every on-disk artifact codec in the crate is built from (the pipeline
//! store's typed artifact payloads, the `sym::persist` term-graph images,
//! the simulator's `DecodedKernel` form).
//!
//! The reader is *total*: every accessor returns `Option` and a corrupt
//! or truncated buffer can only ever produce `None`, never a panic or an
//! attacker-chosen allocation (`len` refuses counts the remaining buffer
//! cannot possibly hold).

/// Append-only little-endian writer.
#[derive(Debug, Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn i64(&mut self, v: i64) {
        self.u64(v as u64);
    }
    pub fn i128(&mut self, v: i128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Bounds-checked little-endian reader over a borrowed buffer.
#[derive(Debug)]
pub struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    pub fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.i.checked_add(n)?;
        let s = self.b.get(self.i..end)?;
        self.i = end;
        Some(s)
    }
    pub fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }
    pub fn bool(&mut self) -> Option<bool> {
        match self.u8()? {
            0 => Some(false),
            1 => Some(true),
            _ => None,
        }
    }
    pub fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }
    pub fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }
    pub fn i64(&mut self) -> Option<i64> {
        Some(self.u64()? as i64)
    }
    pub fn i128(&mut self) -> Option<i128> {
        Some(i128::from_le_bytes(self.take(16)?.try_into().ok()?))
    }
    pub fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }
    /// A length prefix, refused when the remaining buffer cannot possibly
    /// hold that many items — a corrupt length must not drive an OOM
    /// allocation through `Vec::with_capacity`.
    pub fn len(&mut self) -> Option<usize> {
        let n = self.u64()?;
        (n <= (self.b.len() - self.i) as u64).then_some(n as usize)
    }
    pub fn str(&mut self) -> Option<&'a str> {
        let n = self.len()?;
        std::str::from_utf8(self.take(n)?).ok()
    }
    /// Bytes consumed so far.
    pub fn pos(&self) -> usize {
        self.i
    }
    pub fn done(&self) -> bool {
        self.i == self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_primitives() {
        let mut e = Enc::default();
        e.u8(7);
        e.bool(true);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX - 3);
        e.i64(-42);
        e.i128(-(1i128 << 100));
        e.f64(1.5);
        e.str("hello");
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u8(), Some(7));
        assert_eq!(d.bool(), Some(true));
        assert_eq!(d.u32(), Some(0xDEAD_BEEF));
        assert_eq!(d.u64(), Some(u64::MAX - 3));
        assert_eq!(d.i64(), Some(-42));
        assert_eq!(d.i128(), Some(-(1i128 << 100)));
        assert_eq!(d.f64(), Some(1.5));
        assert_eq!(d.str(), Some("hello"));
        assert!(d.done());
    }

    #[test]
    fn truncation_yields_none_not_panic() {
        let mut e = Enc::default();
        e.u64(123);
        e.str("abcdef");
        for cut in 0..e.buf.len() {
            let mut d = Dec::new(&e.buf[..cut]);
            // whatever sequence is attempted, it ends in None
            let _ = d.u64().and_then(|_| d.str().map(|s| s.len()));
            assert!(d.pos() <= cut);
        }
    }

    #[test]
    fn oversized_length_is_refused() {
        let mut e = Enc::default();
        e.u64(u64::MAX); // absurd length prefix
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.len(), None);
        let mut d2 = Dec::new(&e.buf);
        assert_eq!(d2.str(), None);
    }

    #[test]
    fn bad_bool_is_refused() {
        let mut d = Dec::new(&[2]);
        assert_eq!(d.bool(), None);
    }
}
