//! Filesystem seam for the artifact store — a small trait every
//! [`crate::pipeline::DiskStore`] filesystem operation routes through,
//! with a real implementation and a deterministic fault-injecting one.
//!
//! The disk layer's contract is "an accelerator, never a correctness
//! dependency": any IO failure must degrade to recompute with bit-exact
//! results, never a panic, never an accepted-corrupt artifact. That
//! invariant is only worth stating if it can be *driven*: [`FaultFs`]
//! wraps any [`Vfs`] and injects failures on a deterministic, seeded
//! schedule — flat errors (a simulated `ENOSPC`), torn writes that
//! persist a prefix and then report failure, and crash-point writes that
//! persist a prefix and report *success* (the aftermath of a process
//! dying between the data syscalls and the rename reaching disk). The
//! fault-injection property suites (`tests/fault_store.rs`) run the whole
//! pipeline through every class.
//!
//! Everything here is `std`-only and the trait is object-safe on purpose:
//! the store holds an `Arc<dyn Vfs>` so tests swap the seam without a
//! type parameter spreading through the pipeline.

use crate::util::Rng;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::SystemTime;

/// The slice of `std::fs::Metadata` the store consumes.
#[derive(Debug, Clone, Copy)]
pub struct FileMeta {
    pub len: u64,
    pub modified: SystemTime,
}

/// The filesystem operations the artifact store performs. Implementations
/// must be thread-safe; paths are always absolute (the store roots them).
pub trait Vfs: std::fmt::Debug + Send + Sync {
    /// Whole-file read.
    fn read(&self, p: &Path) -> io::Result<Vec<u8>>;
    /// Whole-file write (create or truncate).
    fn write(&self, p: &Path, bytes: &[u8]) -> io::Result<()>;
    /// Exclusive create (`O_EXCL`): fails with `AlreadyExists` when the
    /// path is taken — the primitive the cross-process lock is built on.
    fn create_new(&self, p: &Path, bytes: &[u8]) -> io::Result<()>;
    /// Create-or-truncate an empty file (the `.lru` touch markers — only
    /// the mtime matters).
    fn touch(&self, p: &Path) -> io::Result<()>;
    /// Atomic rename within one directory.
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    fn remove_file(&self, p: &Path) -> io::Result<()>;
    fn create_dir_all(&self, p: &Path) -> io::Result<()>;
    fn metadata(&self, p: &Path) -> io::Result<FileMeta>;
    /// The *files* directly under `p` (directories are skipped), each
    /// with its metadata. Entries whose metadata cannot be read are
    /// silently dropped — a file deleted between the directory read and
    /// the stat is indistinguishable from one that was never there.
    fn read_dir(&self, p: &Path) -> io::Result<Vec<(PathBuf, FileMeta)>>;
}

// ---------------------------------------------------------------------------
// Real implementation
// ---------------------------------------------------------------------------

/// `std::fs`-backed implementation — the production seam.
#[derive(Debug, Default, Clone, Copy)]
pub struct RealFs;

impl Vfs for RealFs {
    fn read(&self, p: &Path) -> io::Result<Vec<u8>> {
        std::fs::read(p)
    }

    fn write(&self, p: &Path, bytes: &[u8]) -> io::Result<()> {
        std::fs::write(p, bytes)
    }

    fn create_new(&self, p: &Path, bytes: &[u8]) -> io::Result<()> {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(p)?;
        f.write_all(bytes)
    }

    fn touch(&self, p: &Path) -> io::Result<()> {
        std::fs::File::create(p).map(|_| ())
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }

    fn remove_file(&self, p: &Path) -> io::Result<()> {
        std::fs::remove_file(p)
    }

    fn create_dir_all(&self, p: &Path) -> io::Result<()> {
        std::fs::create_dir_all(p)
    }

    fn metadata(&self, p: &Path) -> io::Result<FileMeta> {
        let m = std::fs::metadata(p)?;
        Ok(FileMeta {
            len: m.len(),
            modified: m.modified().unwrap_or(SystemTime::UNIX_EPOCH),
        })
    }

    fn read_dir(&self, p: &Path) -> io::Result<Vec<(PathBuf, FileMeta)>> {
        let mut out = Vec::new();
        for e in std::fs::read_dir(p)?.flatten() {
            let Ok(m) = e.metadata() else { continue };
            if !m.is_file() {
                continue;
            }
            out.push((
                e.path(),
                FileMeta {
                    len: m.len(),
                    modified: m.modified().unwrap_or(SystemTime::UNIX_EPOCH),
                },
            ));
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Operation classes faults are scheduled against (one call counter per
/// class, so "fail the 3rd rename" is independent of how many reads
/// happened first).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultOp {
    Read,
    Write,
    CreateNew,
    Touch,
    Rename,
    Remove,
    CreateDirAll,
    Metadata,
    ReadDir,
}

pub const FAULT_OPS: [FaultOp; 9] = [
    FaultOp::Read,
    FaultOp::Write,
    FaultOp::CreateNew,
    FaultOp::Touch,
    FaultOp::Rename,
    FaultOp::Remove,
    FaultOp::CreateDirAll,
    FaultOp::Metadata,
    FaultOp::ReadDir,
];

impl FaultOp {
    fn index(self) -> usize {
        match self {
            FaultOp::Read => 0,
            FaultOp::Write => 1,
            FaultOp::CreateNew => 2,
            FaultOp::Touch => 3,
            FaultOp::Rename => 4,
            FaultOp::Remove => 5,
            FaultOp::CreateDirAll => 6,
            FaultOp::Metadata => 7,
            FaultOp::ReadDir => 8,
        }
    }
}

/// What an injected fault does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Plain failure: the operation reports an error and (for writes)
    /// leaves no bytes behind.
    Error,
    /// Simulated `ENOSPC`: like [`FaultKind::Error`] but with the
    /// out-of-space message the logs would show in production.
    Enospc,
    /// Torn write: the first `K` bytes reach the file, then the call
    /// reports failure (short write / interrupted syscall). Only
    /// meaningful on `Write`/`CreateNew`; behaves like `Error` elsewhere.
    Torn(usize),
    /// Crash-point write: the first `K` bytes reach the file and the call
    /// reports **success** — the aftermath of a crash (or a non-atomic
    /// filesystem) between the data write and its durability. The caller
    /// proceeds to rename a truncated file into place; the store's
    /// checksums must catch it on the next load. Only meaningful on
    /// `Write`/`CreateNew`; behaves like a silent no-op elsewhere.
    Crash(usize),
}

/// One scheduled fault: fire on the `nth` call (0-based) of `op`'s class.
#[derive(Debug, Clone, Copy)]
pub struct FaultRule {
    pub op: FaultOp,
    pub nth: u64,
    pub kind: FaultKind,
}

#[derive(Debug)]
struct FaultState {
    rules: Vec<FaultRule>,
    /// Deterministic random mode: every armed call faults with
    /// probability `1/rate` under this seeded stream.
    random: Option<(Rng, u64)>,
    armed: bool,
}

/// A [`Vfs`] decorator that injects faults on a deterministic schedule —
/// explicit [`FaultRule`]s, a seeded random mode, or both. Starts
/// *disarmed* so the store under test can be constructed cleanly; call
/// [`FaultFs::arm`] once the plan is set.
#[derive(Debug)]
pub struct FaultFs {
    inner: std::sync::Arc<dyn Vfs>,
    state: Mutex<FaultState>,
    seen: [AtomicU64; FAULT_OPS.len()],
    injected: AtomicU64,
}

impl FaultFs {
    pub fn new(inner: std::sync::Arc<dyn Vfs>) -> std::sync::Arc<FaultFs> {
        std::sync::Arc::new(FaultFs {
            inner,
            state: Mutex::new(FaultState {
                rules: Vec::new(),
                random: None,
                armed: false,
            }),
            seen: Default::default(),
            injected: AtomicU64::new(0),
        })
    }

    /// Wrap the real filesystem.
    pub fn real() -> std::sync::Arc<FaultFs> {
        FaultFs::new(std::sync::Arc::new(RealFs))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, FaultState> {
        // a panicking pipeline thread must not wedge the seam
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Schedule explicit faults. Cumulative with earlier rules.
    pub fn push_rules(&self, rules: &[FaultRule]) {
        self.lock().rules.extend_from_slice(rules);
    }

    /// Enable the seeded random mode: while armed, every operation faults
    /// with probability `1/rate`, with the fault kind drawn from the same
    /// stream (deterministic for a given seed and call sequence).
    pub fn randomize(&self, seed: u64, rate: u64) {
        self.lock().random = Some((Rng::new(seed), rate.max(1)));
    }

    /// Arm or disarm the injector (counters keep running either way).
    pub fn arm(&self, on: bool) {
        self.lock().armed = on;
    }

    /// Total faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Calls seen for one op class.
    pub fn seen(&self, op: FaultOp) -> u64 {
        self.seen[op.index()].load(Ordering::Relaxed)
    }

    /// Decide whether the current call (op class, call index `n`) faults.
    fn decide(&self, op: FaultOp) -> Option<FaultKind> {
        let n = self.seen[op.index()].fetch_add(1, Ordering::Relaxed);
        let mut st = self.lock();
        if !st.armed {
            return None;
        }
        if let Some(i) = st
            .rules
            .iter()
            .position(|r| r.op == op && r.nth == n)
        {
            let kind = st.rules.remove(i).kind;
            drop(st);
            self.injected.fetch_add(1, Ordering::Relaxed);
            return Some(kind);
        }
        if let Some((rng, rate)) = &mut st.random {
            if rng.below(*rate) == 0 {
                let kind = match rng.below(4) {
                    0 => FaultKind::Error,
                    1 => FaultKind::Enospc,
                    2 => FaultKind::Torn(rng.below(64) as usize),
                    _ => FaultKind::Crash(rng.below(64) as usize),
                };
                drop(st);
                self.injected.fetch_add(1, Ordering::Relaxed);
                return Some(kind);
            }
        }
        None
    }

    fn fail(kind: FaultKind) -> io::Error {
        match kind {
            FaultKind::Enospc => io::Error::new(
                io::ErrorKind::Other,
                "injected fault: no space left on device (ENOSPC)",
            ),
            _ => io::Error::new(io::ErrorKind::Other, "injected fault"),
        }
    }

    /// Apply a fault to a write-shaped op: persist a prefix for
    /// `Torn`/`Crash`, then report failure (or fake success for `Crash`).
    fn faulted_write(
        &self,
        p: &Path,
        bytes: &[u8],
        kind: FaultKind,
        exclusive: bool,
    ) -> io::Result<()> {
        match kind {
            FaultKind::Error | FaultKind::Enospc => Err(Self::fail(kind)),
            FaultKind::Torn(k) | FaultKind::Crash(k) => {
                let k = k.min(bytes.len());
                let res = if exclusive {
                    self.inner.create_new(p, &bytes[..k])
                } else {
                    self.inner.write(p, &bytes[..k])
                };
                match kind {
                    FaultKind::Crash(_) => res, // partial bytes, reported OK
                    _ => res.and(Err(Self::fail(kind))),
                }
            }
        }
    }
}

impl Vfs for FaultFs {
    fn read(&self, p: &Path) -> io::Result<Vec<u8>> {
        match self.decide(FaultOp::Read) {
            Some(k) => Err(Self::fail(k)),
            None => self.inner.read(p),
        }
    }

    fn write(&self, p: &Path, bytes: &[u8]) -> io::Result<()> {
        match self.decide(FaultOp::Write) {
            Some(k) => self.faulted_write(p, bytes, k, false),
            None => self.inner.write(p, bytes),
        }
    }

    fn create_new(&self, p: &Path, bytes: &[u8]) -> io::Result<()> {
        match self.decide(FaultOp::CreateNew) {
            Some(k) => self.faulted_write(p, bytes, k, true),
            None => self.inner.create_new(p, bytes),
        }
    }

    fn touch(&self, p: &Path) -> io::Result<()> {
        match self.decide(FaultOp::Touch) {
            Some(k) => Err(Self::fail(k)),
            None => self.inner.touch(p),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        match self.decide(FaultOp::Rename) {
            Some(k) => Err(Self::fail(k)),
            None => self.inner.rename(from, to),
        }
    }

    fn remove_file(&self, p: &Path) -> io::Result<()> {
        match self.decide(FaultOp::Remove) {
            Some(k) => Err(Self::fail(k)),
            None => self.inner.remove_file(p),
        }
    }

    fn create_dir_all(&self, p: &Path) -> io::Result<()> {
        match self.decide(FaultOp::CreateDirAll) {
            Some(k) => Err(Self::fail(k)),
            None => self.inner.create_dir_all(p),
        }
    }

    fn metadata(&self, p: &Path) -> io::Result<FileMeta> {
        match self.decide(FaultOp::Metadata) {
            Some(k) => Err(Self::fail(k)),
            None => self.inner.metadata(p),
        }
    }

    fn read_dir(&self, p: &Path) -> io::Result<Vec<(PathBuf, FileMeta)>> {
        match self.decide(FaultOp::ReadDir) {
            Some(k) => Err(Self::fail(k)),
            None => self.inner.read_dir(p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ptxasw-vfs-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn realfs_roundtrip_and_listing() {
        let d = tmp("real");
        let fs = RealFs;
        let f = d.join("a.bin");
        fs.write(&f, b"hello").unwrap();
        assert_eq!(fs.read(&f).unwrap(), b"hello");
        assert_eq!(fs.metadata(&f).unwrap().len, 5);
        // subdirectories are not listed as files
        fs.create_dir_all(&d.join("sub")).unwrap();
        let listed = fs.read_dir(&d).unwrap();
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].0, f);
        // exclusive create refuses an existing path
        assert_eq!(
            fs.create_new(&f, b"x").unwrap_err().kind(),
            io::ErrorKind::AlreadyExists
        );
        fs.rename(&f, &d.join("b.bin")).unwrap();
        assert!(fs.read(&f).is_err());
        fs.remove_file(&d.join("b.bin")).unwrap();
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn fault_rules_fire_on_the_nth_call_only() {
        let d = tmp("nth");
        let fs = FaultFs::real();
        fs.push_rules(&[FaultRule {
            op: FaultOp::Write,
            nth: 1,
            kind: FaultKind::Enospc,
        }]);
        fs.arm(true);
        let f = d.join("x");
        fs.write(&f, b"first").unwrap(); // call 0: clean
        let err = fs.write(&f, b"second").unwrap_err(); // call 1: faulted
        assert!(err.to_string().contains("ENOSPC"));
        fs.write(&f, b"third").unwrap(); // rule is one-shot
        assert_eq!(fs.injected(), 1);
        assert_eq!(fs.seen(FaultOp::Write), 3);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_write_persists_prefix_and_reports_failure() {
        let d = tmp("torn");
        let fs = FaultFs::real();
        fs.push_rules(&[FaultRule {
            op: FaultOp::Write,
            nth: 0,
            kind: FaultKind::Torn(3),
        }]);
        fs.arm(true);
        let f = d.join("x");
        assert!(fs.write(&f, b"payload").is_err());
        assert_eq!(std::fs::read(&f).unwrap(), b"pay", "prefix must persist");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn crash_write_persists_prefix_and_reports_success() {
        let d = tmp("crash");
        let fs = FaultFs::real();
        fs.push_rules(&[FaultRule {
            op: FaultOp::Write,
            nth: 0,
            kind: FaultKind::Crash(4),
        }]);
        fs.arm(true);
        let f = d.join("x");
        fs.write(&f, b"payload").unwrap(); // lies about success
        assert_eq!(std::fs::read(&f).unwrap(), b"payl", "truncated file left behind");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn disarmed_injector_is_transparent_and_random_mode_is_deterministic() {
        let d = tmp("rand");
        let fs = FaultFs::real();
        fs.randomize(0x5eed, 3);
        let f = d.join("x");
        // disarmed: no faults regardless of the schedule
        for _ in 0..16 {
            fs.write(&f, b"ok").unwrap();
        }
        assert_eq!(fs.injected(), 0);

        // armed: the same seed and call sequence faults identically
        let run = |seed: u64| {
            let fs = FaultFs::real();
            fs.randomize(seed, 3);
            fs.arm(true);
            let mut pattern = Vec::new();
            for i in 0..64 {
                let p = d.join(format!("r{i}"));
                pattern.push(fs.write(&p, b"abcdefgh").is_ok());
            }
            pattern
        };
        assert_eq!(run(7), run(7), "same seed, same fault schedule");
        assert!(
            run(7).iter().any(|ok| !ok),
            "rate 3 over 64 calls must fault at least once"
        );
        let _ = std::fs::remove_dir_all(&d);
    }
}
