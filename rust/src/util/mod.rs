//! Small shared utilities: deterministic RNG and a property-test harness.
//!
//! The offline crate universe has no `rand`/`proptest`, so property-based
//! tests run on a hand-rolled xorshift generator. Failures print the seed so
//! a shrunk case can be replayed with `Rng::new(seed)`.

pub mod codec;
pub mod json;
pub mod rng;
pub mod vfs;

pub use codec::{Dec, Enc};
pub use json::Json;
pub use rng::Rng;
pub use vfs::{FaultFs, FaultKind, FaultOp, FaultRule, RealFs, Vfs};

/// FNV-1a hasher — far cheaper than SipHash for the short register-name
/// keys on the simulator/emulator hot paths (no DoS concern: inputs are
/// our own PTX).
#[derive(Debug, Default, Clone)]
pub struct Fnv(u64);

impl std::hash::Hasher for Fnv {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { 0xcbf2_9ce4_8422_2325 } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        self.0 = h;
    }
}

/// Dual-stream FNV-1a with a splitmix64 finisher: the stable 128-bit key
/// scheme shared by kernel fingerprints (`ptx::kernel_fingerprint`),
/// workload fingerprints (`suite::workload_fingerprint`) and the disk
/// store's keys (`pipeline::store::KeyBuilder`). One implementation on
/// purpose: these keys must stay byte-identical run-to-run and
/// process-to-process (never the process-seeded `DefaultHasher`), and the
/// call sites must never drift apart.
#[derive(Debug, Clone)]
pub struct Fnv128 {
    h1: u64,
    h2: u64,
}

impl Default for Fnv128 {
    fn default() -> Fnv128 {
        Fnv128::new()
    }
}

impl Fnv128 {
    pub fn new() -> Fnv128 {
        Fnv128 {
            h1: 0xcbf2_9ce4_8422_2325,
            h2: 0x8422_2325_cbf2_9ce4,
        }
    }

    pub fn write(&mut self, bytes: &[u8]) -> &mut Fnv128 {
        for &b in bytes {
            self.h1 = (self.h1 ^ b as u64).wrapping_mul(0x1000_0000_01b3);
            self.h2 = (self.h2 ^ b as u64).wrapping_mul(0x1000_01b3_0000_01b3);
        }
        self
    }

    pub fn write_u64(&mut self, v: u64) -> &mut Fnv128 {
        self.write(&v.to_le_bytes())
    }

    /// The finalized 128-bit key as two avalanched words.
    pub fn finish(&self) -> (u64, u64) {
        (mix64(self.h1), mix64(self.h2))
    }
}

/// splitmix64 finalizer — avalanches the weak tail bits of FNV.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// One-shot FNV-1a 64 of a byte slice — the checksum flavour of [`Fnv`],
/// kept here so the constants live in exactly one module.
pub fn fnv64(bytes: &[u8]) -> u64 {
    use std::hash::Hasher;
    let mut h = Fnv::default();
    h.write(bytes);
    h.finish()
}

/// `BuildHasher` for [`Fnv`].
pub type FnvBuild = std::hash::BuildHasherDefault<Fnv>;
/// HashMap with FNV hashing.
pub type FnvMap<K, V> = std::collections::HashMap<K, V, FnvBuild>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv128_is_deterministic_and_chunking_invariant() {
        let mut a = Fnv128::new();
        a.write(b"hello world");
        let mut b = Fnv128::new();
        b.write(b"hello ");
        b.write(b"world");
        assert_eq!(a.finish(), b.finish(), "chunking must not change the key");

        let mut c = Fnv128::new();
        c.write(b"hello worlc");
        assert_ne!(a.finish(), c.finish());

        let mut d = Fnv128::new();
        d.write_u64(7);
        let mut e = Fnv128::new();
        e.write(&7u64.to_le_bytes());
        assert_eq!(d.finish(), e.finish(), "write_u64 is little-endian bytes");
    }
}

/// Run `f` for `cases` deterministic random cases; panic with the seed on
/// the first failure. Poor man's proptest.
pub fn check_cases(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64
            .wrapping_mul(case + 1)
            .wrapping_add(0xD1B5_4A32_D192_ED03);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property `{name}` failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}
