//! Small shared utilities: deterministic RNG and a property-test harness.
//!
//! The offline crate universe has no `rand`/`proptest`, so property-based
//! tests run on a hand-rolled xorshift generator. Failures print the seed so
//! a shrunk case can be replayed with `Rng::new(seed)`.

pub mod rng;

pub use rng::Rng;

/// FNV-1a hasher — far cheaper than SipHash for the short register-name
/// keys on the simulator/emulator hot paths (no DoS concern: inputs are
/// our own PTX).
#[derive(Default, Clone)]
pub struct Fnv(u64);

impl std::hash::Hasher for Fnv {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { 0xcbf2_9ce4_8422_2325 } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        self.0 = h;
    }
}

/// `BuildHasher` for [`Fnv`].
pub type FnvBuild = std::hash::BuildHasherDefault<Fnv>;
/// HashMap with FNV hashing.
pub type FnvMap<K, V> = std::collections::HashMap<K, V, FnvBuild>;

/// Run `f` for `cases` deterministic random cases; panic with the seed on
/// the first failure. Poor man's proptest.
pub fn check_cases(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64
            .wrapping_mul(case + 1)
            .wrapping_add(0xD1B5_4A32_D192_ED03);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property `{name}` failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}
