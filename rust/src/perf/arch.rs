//! GPU architecture descriptors for the four generations the paper
//! evaluates (Table 1 + the microbenchmark literature it cites: Jia et
//! al. 2018, Wong et al. 2010).
//!
//! Only *relative* latencies matter for reproducing the paper's shapes:
//! which benchmarks win on which architecture, where Volta degrades, why
//! Maxwell's texture-stall kernels fly. Absolute clocks are not claimed.

/// Latency/throughput parameters of one GPU generation.
#[derive(Debug, Clone)]
pub struct Arch {
    pub name: &'static str,
    pub sm: &'static str,
    /// Dependent-ALU latency (int/fp32 pipeline depth).
    pub alu_lat: u32,
    /// Slow ALU (div/rem/sfu) latency.
    pub sfu_lat: u32,
    /// Warp shuffle latency (Table 1 "Shuffle (up)").
    pub shuffle_lat: u32,
    /// Shared-memory load latency (Table 1 "SM Read").
    pub shared_lat: u32,
    /// L1 hit latency (Table 1 "L1 Hit") — plain `ld.global`.
    pub l1_lat: u32,
    /// Read-only / texture-path latency — `ld.global.nc`. On Maxwell and
    /// Pascal this path is the slow one the paper's §8.2/§8.3 blame.
    pub tex_lat: u32,
    /// Full miss latency to device memory.
    pub gmem_lat: u32,
    /// Fraction (percent) of warp loads that miss the near cache.
    pub miss_pct: u32,
    /// Per-warp outstanding-load budget; exceeding it throttles (§8.1).
    pub max_outstanding: u32,
    /// Cycles lost re-fetching after a taken branch (instruction fetch).
    pub fetch_stall: u32,
    /// Extra latency on shuffles/predicated issues from register bank
    /// conflicts (the Pascal "Other" latency of §8.3).
    pub bank_conflict: u32,
    /// Register file per SM (32-bit regs).
    pub regs_per_sm: u32,
    /// Max resident warps per SM.
    pub max_warps: u32,
    /// Architectural register overhead added to max-live for the SASS
    /// register estimate.
    pub reg_overhead: u32,
    /// Cycles per 32-byte sector through the L1/texture pipeline, charged
    /// per *request* (no reuse discount — a hit still occupies the unit).
    /// This is the resource shuffles free up: Maxwell/Pascal's texture
    /// path is slow, Volta's unified L1 is wide (§8.2–8.4).
    pub sector_cycles: f64,
    /// Cycles per *unique* 32-byte sector of DRAM traffic per warp —
    /// per-SM DRAM bandwidth, which shuffle synthesis cannot reduce
    /// (derived from BW/SMs/clock: K40 25 B/cy, TITAN X 14, P100 10,
    /// V100 7.5).
    pub dram_sector_cycles: f64,
    /// Warp-instructions issued per cycle per SM (scheduler count).
    pub issue_width: f64,
}

impl Arch {
    /// Effective latency of a plain global load (L1 path).
    pub fn global_load_lat(&self) -> u32 {
        self.l1_lat + self.gmem_lat * self.miss_pct / 100
    }

    /// Effective latency of a read-only (`.nc`) load (texture path).
    pub fn nc_load_lat(&self) -> u32 {
        self.tex_lat + self.gmem_lat * self.miss_pct / 100
    }

    /// Occupancy (fraction of max warps) for a per-thread register count.
    pub fn occupancy(&self, regs_per_thread: u32) -> f64 {
        let regs = regs_per_thread.max(16);
        // register allocation granularity of 8
        let regs = (regs + 7) / 8 * 8;
        let warps_by_regs = self.regs_per_sm / (regs * 32);
        (warps_by_regs.min(self.max_warps)) as f64 / self.max_warps as f64
    }
}

/// NVIDIA Tesla K40c (shuffle latencies measured on K40c per the paper).
pub const KEPLER: Arch = Arch {
    name: "Kepler",
    sm: "sm_35",
    alu_lat: 9,
    sfu_lat: 26,
    shuffle_lat: 24,
    shared_lat: 26,
    l1_lat: 35,
    tex_lat: 108,
    gmem_lat: 230,
    miss_pct: 24,
    max_outstanding: 5,
    fetch_stall: 8,
    bank_conflict: 0,
    regs_per_sm: 65536,
    max_warps: 64,
    reg_overhead: 10,
    sector_cycles: 0.6,
    dram_sector_cycles: 1.6,
    issue_width: 4.0,
};

/// NVIDIA TITAN X (Maxwell).
pub const MAXWELL: Arch = Arch {
    name: "Maxwell",
    sm: "sm_50",
    alu_lat: 6,
    sfu_lat: 20,
    shuffle_lat: 33,
    shared_lat: 23,
    l1_lat: 82,
    tex_lat: 106,
    gmem_lat: 368,
    miss_pct: 20,
    max_outstanding: 8,
    fetch_stall: 6,
    bank_conflict: 0,
    regs_per_sm: 65536,
    max_warps: 64,
    reg_overhead: 10,
    sector_cycles: 1.0,
    dram_sector_cycles: 2.0,
    issue_width: 4.0,
};

/// NVIDIA Tesla P100.
pub const PASCAL: Arch = Arch {
    name: "Pascal",
    sm: "sm_60",
    alu_lat: 6,
    sfu_lat: 20,
    shuffle_lat: 33,
    shared_lat: 24,
    l1_lat: 82,
    tex_lat: 106,
    gmem_lat: 350,
    miss_pct: 18,
    max_outstanding: 8,
    fetch_stall: 6,
    bank_conflict: 14,
    regs_per_sm: 65536,
    max_warps: 64,
    reg_overhead: 10,
    sector_cycles: 0.7,
    dram_sector_cycles: 2.2,
    issue_width: 4.0,
};

/// NVIDIA Tesla V100 (SXM2).
pub const VOLTA: Arch = Arch {
    name: "Volta",
    sm: "sm_70",
    alu_lat: 4,
    sfu_lat: 16,
    shuffle_lat: 22,
    shared_lat: 19,
    l1_lat: 28,
    tex_lat: 28,
    gmem_lat: 375,
    miss_pct: 16,
    max_outstanding: 10,
    fetch_stall: 10,
    bank_conflict: 0,
    regs_per_sm: 65536,
    max_warps: 64,
    reg_overhead: 10,
    sector_cycles: 0.25,
    dram_sector_cycles: 1.0,
    issue_width: 4.0,
};

/// All four generations in the paper's order.
pub fn all() -> [&'static Arch; 4] {
    [&KEPLER, &MAXWELL, &PASCAL, &VOLTA]
}

pub fn by_name(name: &str) -> Option<&'static Arch> {
    all()
        .into_iter()
        .find(|a| a.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_orderings_hold() {
        // Table 1: shuffle beats shared memory only on Kepler... actually
        // shuffle < L1 everywhere except Volta where they're close;
        // the Maxwell/Pascal L1 is the slow one.
        for a in all() {
            assert!(a.shuffle_lat < a.l1_lat + 1, "{}", a.name);
        }
        assert!(MAXWELL.l1_lat > KEPLER.l1_lat);
        assert!(PASCAL.l1_lat > VOLTA.l1_lat);
        // Volta has the lowest latencies across the board
        for a in [&KEPLER, &MAXWELL, &PASCAL] {
            assert!(VOLTA.shuffle_lat <= a.shuffle_lat);
            assert!(VOLTA.shared_lat <= a.shared_lat);
            assert!(VOLTA.l1_lat <= a.l1_lat);
        }
    }

    #[test]
    fn occupancy_decreases_with_registers() {
        for a in all() {
            let o32 = a.occupancy(32);
            let o64 = a.occupancy(64);
            let o128 = a.occupancy(128);
            assert!(o32 >= o64 && o64 >= o128, "{}", a.name);
            assert!(o32 <= 1.0 && o128 > 0.0);
        }
        // 32 regs → 64 warps exactly on 64k-reg SMs
        assert!((KEPLER.occupancy(32) - 1.0).abs() < 1e-9);
        // 64 regs → 32 warps → 50%
        assert!((KEPLER.occupancy(64) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("volta").unwrap().name, "Volta");
        assert!(by_name("Ampere").is_none());
    }
}
