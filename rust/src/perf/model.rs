//! Scoreboard latency model with stall attribution (Figures 2 & 3).
//!
//! Replays the simulator's per-warp issue trace through an in-order
//! single-issue scoreboard. `WarpEvent::stmt` always indexes the kernel
//! *body statement* regardless of which simulator engine produced the
//! trace (the decoded engine keeps a micro-op → statement side table for
//! exactly this reason), so the replay below never changes with the
//! engine. Every instruction issues when its source
//! registers are ready and the pipeline is free; the wait is attributed to
//! the stall reason the profiler would sample (execution dependency,
//! memory dependency, texture, memory throttle, pipe busy, instruction
//! fetch, other). Multi-warp overlap is applied afterwards: with `W`
//! resident warps (from the occupancy estimate), the effective time is
//! `max(issue-bound, latency-bound / W)` — the standard latency-hiding
//! approximation.

use super::arch::Arch;
use crate::emu::env::RegInterner;
use crate::emu::induction::written_reg;
use crate::ptx::ast::{Kernel, Op, Space, Statement};
use crate::shuffle::{Cfg, Liveness};
use crate::sim::WarpEvent;

/// Stall reasons, in the paper's Figure 3 vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stall {
    ExecDependency,
    MemDependency,
    Texture,
    MemThrottle,
    PipeBusy,
    InstructionFetch,
    Synchronization,
    Other,
}

pub const STALL_KINDS: [Stall; 8] = [
    Stall::ExecDependency,
    Stall::MemDependency,
    Stall::Texture,
    Stall::MemThrottle,
    Stall::PipeBusy,
    Stall::InstructionFetch,
    Stall::Synchronization,
    Stall::Other,
];

impl Stall {
    pub fn name(self) -> &'static str {
        match self {
            Stall::ExecDependency => "exec_dep",
            Stall::MemDependency => "mem_dep",
            Stall::Texture => "texture",
            Stall::MemThrottle => "mem_throttle",
            Stall::PipeBusy => "pipe_busy",
            Stall::InstructionFetch => "ifetch",
            Stall::Synchronization => "sync",
            Stall::Other => "other",
        }
    }

    fn index(self) -> usize {
        STALL_KINDS.iter().position(|&s| s == self).unwrap()
    }
}

/// Instruction classes the scoreboard distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Class {
    Alu,
    Sfu,
    LdGlobal,
    LdNc,
    LdShared,
    St,
    Shfl,
    Bra,
    Bar,
    Nop,
}

fn classify(op: &Op) -> Class {
    match op {
        Op::Ld { space, nc, .. } => match space {
            Space::Param => Class::Alu, // constant-bank read
            Space::Shared => Class::LdShared,
            _ => {
                if *nc {
                    Class::LdNc
                } else {
                    Class::LdGlobal
                }
            }
        },
        Op::St { .. } => Class::St,
        Op::IntBin { op, .. } => match op {
            crate::ptx::ast::IntBinOp::Div | crate::ptx::ast::IntBinOp::Rem => Class::Sfu,
            _ => Class::Alu,
        },
        Op::FltUn { op, .. } => match op {
            crate::ptx::ast::FltUnOp::Neg | crate::ptx::ast::FltUnOp::Abs => Class::Alu,
            _ => Class::Sfu,
        },
        Op::FltBin { op: crate::ptx::ast::FltBinOp::Div, .. } => Class::Sfu,
        Op::Shfl { .. } => Class::Shfl,
        Op::Bra { .. } => Class::Bra,
        Op::BarSync { .. } => Class::Bar,
        Op::Ret | Op::Exit => Class::Nop,
        _ => Class::Alu,
    }
}

/// Per-kernel, per-architecture performance estimate.
#[derive(Debug, Clone)]
pub struct PerfReport {
    pub arch: &'static str,
    /// Cycles a single warp needs (issue + stalls), summed over the traced
    /// warps.
    pub serial_cycles: f64,
    /// Pure issue cycles (throughput floor).
    pub issue_cycles: f64,
    /// Stall cycles by reason.
    pub stalls: [f64; 8],
    /// Occupancy from the register estimate.
    pub occupancy: f64,
    /// Estimated SASS registers per thread (max-live + overhead).
    pub regs_per_thread: u32,
    /// L1/texture-pipeline cycles: 32-byte sectors *requested*, times the
    /// per-arch pipe cost. This is the resource shuffle synthesis frees —
    /// corner-case loads request 1 sector instead of 4 per warp.
    pub mem_cycles: f64,
    /// DRAM cycles: *unique* sectors touched per warp, times per-SM DRAM
    /// bandwidth cost. Shuffles cannot reduce this floor.
    pub dram_cycles: f64,
    /// Latency-hidden effective cycles (the Figure 2 quantity):
    /// `max(issue, serial/W, mem)`.
    pub effective_cycles: f64,
}

impl PerfReport {
    /// Fraction of serial time attributed to each stall reason.
    pub fn stall_fractions(&self) -> Vec<(&'static str, f64)> {
        let total: f64 = self.serial_cycles.max(1.0);
        STALL_KINDS
            .iter()
            .map(|s| (s.name(), self.stalls[s.index()] / total))
            .collect()
    }
}

/// Estimate performance of `kernel` on `arch` given a simulator issue trace.
pub fn model(kernel: &Kernel, trace: &[Vec<WarpEvent>], arch: &Arch) -> PerfReport {
    let mut regs = RegInterner::from_kernel(kernel);
    let cfg = Cfg::build(kernel);
    let live = Liveness::compute(kernel, &cfg, &mut regs);
    let regs_per_thread = live.max_live() + arch.reg_overhead;
    let occupancy = arch.occupancy(regs_per_thread);

    // pre-compute per-statement class + uses/defs
    let n = kernel.body.len();
    let mut class = vec![Class::Nop; n];
    let mut stmt_defs: Vec<Option<u32>> = vec![None; n];
    let uds = crate::shuffle::liveness::use_defs(kernel, &mut regs);
    for (i, st) in kernel.body.iter().enumerate() {
        if let Statement::Instr { op, .. } = st {
            class[i] = classify(op);
            stmt_defs[i] = written_reg(op).map(|r| regs.intern(r));
        }
    }

    let nregs = regs.len();
    let mut issue_cycles = 0f64;
    let mut serial = 0f64;
    let mut stalls = [0f64; 8];
    let mut sectors = 0f64;
    let mut unique_sectors = 0f64;
    // global across warps: models inter-warp reuse through L2
    let mut seen_sectors: std::collections::HashSet<u64> = std::collections::HashSet::new();

    for warp in trace {
        // scoreboard state per warp
        let mut ready = vec![(0u64, Class::Nop); nregs]; // (ready_cycle, producer class)
        let mut now: u64 = 0;
        let mut outstanding: Vec<u64> = Vec::new(); // completion times of loads in flight

        for ev in warp {
            let i = ev.stmt as usize;
            let c = class[i];
            if c == Class::Nop {
                continue;
            }
            issue_cycles += 1.0;
            // predicated-off for the whole warp: issue-only, no latency,
            // no memory traffic, no register update
            if ev.exec == 0 {
                now += 1;
                continue;
            }
            // memory traffic in 32-byte sectors (4-byte coalesced lanes)
            if matches!(c, Class::LdGlobal | Class::LdNc | Class::St) {
                let n = (ev.exec.count_ones() as f64 * 4.0 / 32.0).ceil();
                sectors += n;
                // DRAM traffic: only sectors this warp has not touched yet
                for k in 0..n as u64 {
                    if seen_sectors.insert(ev.addr / 32 + k) {
                        unique_sectors += 1.0;
                    }
                }
            }
            let mut issue_at = now + 1;

            // source-operand readiness
            let mut dep_at = 0u64;
            let mut dep_class = Class::Nop;
            for &u in &uds[i].uses {
                let (r, pc) = ready[u as usize];
                if r > dep_at {
                    dep_at = r;
                    dep_class = pc;
                }
            }
            if dep_at > issue_at {
                let wait = dep_at - issue_at;
                let kind = match dep_class {
                    Class::LdGlobal => Stall::MemDependency,
                    Class::LdNc => Stall::Texture,
                    Class::LdShared => Stall::MemDependency,
                    Class::Shfl => Stall::ExecDependency,
                    Class::Sfu => Stall::PipeBusy,
                    Class::Alu => Stall::ExecDependency,
                    _ => Stall::Other,
                };
                stalls[kind.index()] += wait as f64;
                issue_at = dep_at;
            }

            // memory-throttle: too many loads in flight
            if matches!(c, Class::LdGlobal | Class::LdNc | Class::St) {
                outstanding.retain(|&t| t > issue_at);
                if outstanding.len() >= arch.max_outstanding as usize {
                    let free_at = *outstanding.iter().min().unwrap();
                    if free_at > issue_at {
                        stalls[Stall::MemThrottle.index()] += (free_at - issue_at) as f64;
                        issue_at = free_at;
                        outstanding.retain(|&t| t > issue_at);
                    }
                }
            }

            // instruction-class latency; guarded (corner-case) loads hit
            // lines just fetched by neighbouring warps' full loads, so they
            // see hit latency without the miss surcharge
            let guarded = kernel_stmt_guarded(kernel, i);
            let lat = match c {
                Class::Alu => arch.alu_lat,
                Class::Sfu => arch.sfu_lat,
                Class::LdGlobal => {
                    if guarded {
                        arch.l1_lat
                    } else {
                        arch.global_load_lat()
                    }
                }
                Class::LdNc => {
                    if guarded {
                        arch.tex_lat
                    } else {
                        arch.nc_load_lat()
                    }
                }
                Class::LdShared => arch.shared_lat,
                Class::St => arch.alu_lat,
                Class::Shfl => arch.shuffle_lat + arch.bank_conflict,
                Class::Bra => arch.alu_lat,
                Class::Bar => arch.alu_lat,
                Class::Nop => 0,
            };

            // branch refetch cost (uniform branches still refetch)
            if c == Class::Bra {
                stalls[Stall::InstructionFetch.index()] += arch.fetch_stall as f64;
                issue_at += arch.fetch_stall as u64;
            }
            if c == Class::Bar {
                stalls[Stall::Synchronization.index()] += arch.shared_lat as f64;
                issue_at += arch.shared_lat as u64;
            }
            // register bank pressure on predicated re-issues (Pascal §8.3)
            if arch.bank_conflict > 0 && matches!(c, Class::LdGlobal | Class::LdNc) {
                if guarded {
                    stalls[Stall::Other.index()] += arch.bank_conflict as f64;
                    issue_at += arch.bank_conflict as u64;
                }
            }

            let done_at = issue_at + lat as u64;
            if matches!(c, Class::LdGlobal | Class::LdNc | Class::St) {
                outstanding.push(done_at);
            }
            if let Some(d) = stmt_defs[i] {
                ready[d as usize] = (done_at, c);
            }
            now = issue_at;
        }
        serial += now as f64;
    }

    let resident = (occupancy * arch.max_warps as f64).max(1.0);
    let mem_cycles = sectors * arch.sector_cycles;
    let dram_cycles = unique_sectors * arch.dram_sector_cycles;
    // per-SM latency hiding: resident warps cover stalls; the kernel is
    // bounded below by issue, L1/tex-pipe and DRAM throughput
    let effective = (issue_cycles / arch.issue_width)
        .max(serial / resident)
        .max(mem_cycles)
        .max(dram_cycles);

    PerfReport {
        arch: arch.name,
        serial_cycles: serial,
        issue_cycles,
        stalls,
        occupancy,
        regs_per_thread,
        mem_cycles,
        dram_cycles,
        effective_cycles: effective,
    }
}

fn kernel_stmt_guarded(kernel: &Kernel, i: usize) -> bool {
    matches!(
        kernel.body.get(i),
        Some(Statement::Instr { guard: Some(_), .. })
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::arch::{KEPLER, MAXWELL, VOLTA};
    use crate::ptx::parser::parse_kernel;
    use crate::sim::{run, Allocator, GlobalMem, SimConfig};

    fn trace_of(src: &str, n: usize, block: u32) -> (crate::ptx::ast::Kernel, Vec<Vec<WarpEvent>>) {
        let k = parse_kernel(src).unwrap();
        let mut mem = GlobalMem::new(1 << 20);
        let mut alloc = Allocator::new(&mem);
        let out = alloc.alloc(4 * n as u64);
        let a = alloc.alloc(4 * (n + 64) as u64);
        mem.write_f32s(a, &vec![1.0; n + 64]).unwrap();
        let mut cfg = SimConfig::new(1, block, vec![out, a, n as u64]);
        cfg.record_trace = true;
        let r = run(&k, &cfg, mem).unwrap();
        (k, r.trace)
    }

    const CHAIN: &str = r#"
.visible .entry chain(.param .u64 out, .param .u64 a, .param .u32 n){
.reg .b32 %r<6>; .reg .b64 %rd<6>; .reg .f32 %f<6>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd4, %r4, 4;
add.s64 %rd5, %rd3, %rd4;
ld.global.nc.f32 %f1, [%rd5];
add.f32 %f2, %f1, %f1;
add.f32 %f3, %f2, %f2;
add.f32 %f4, %f3, %f3;
cvta.to.global.u64 %rd3, %rd1;
add.s64 %rd5, %rd3, %rd4;
st.global.f32 [%rd5], %f4;
ret;
}
"#;

    #[test]
    fn texture_dependency_attributed() {
        let (k, trace) = trace_of(CHAIN, 32, 32);
        let rep = model(&k, &trace, &MAXWELL);
        // the add.f32 after the nc load waits on the texture path
        let tex = rep.stalls[Stall::Texture.index()];
        assert!(tex > 0.0, "texture stall expected, got {:?}", rep.stalls);
        // dependent adds create exec-dependency stalls
        assert!(rep.stalls[Stall::ExecDependency.index()] > 0.0);
        assert!(rep.serial_cycles > rep.issue_cycles);
    }

    #[test]
    fn volta_faster_than_maxwell_on_dependent_chain() {
        let (k, trace) = trace_of(CHAIN, 32, 32);
        let m = model(&k, &trace, &MAXWELL);
        let v = model(&k, &trace, &VOLTA);
        assert!(
            v.serial_cycles < m.serial_cycles,
            "volta {} vs maxwell {}",
            v.serial_cycles,
            m.serial_cycles
        );
    }

    #[test]
    fn occupancy_reported() {
        let (k, trace) = trace_of(CHAIN, 32, 32);
        let rep = model(&k, &trace, &KEPLER);
        assert!(rep.occupancy > 0.0 && rep.occupancy <= 1.0);
        assert!(rep.regs_per_thread >= KEPLER.reg_overhead);
        let fr: f64 = rep.stall_fractions().iter().map(|(_, f)| f).sum();
        assert!(fr <= 1.0 + 1e-9);
    }

    /// Fig. 3-style check for the phase-liveness pass: deleting the
    /// `.shared` staging stores and eliding the `bar.sync`s must shrink
    /// both the synchronization-stall column and the serial cycles —
    /// the model prices `Bar` (sync stall + shared latency) and
    /// `LdShared` (shared latency) per trace event, so the eliminated
    /// kernel's shorter trace scores strictly better.
    #[test]
    fn elimination_reduces_sync_stalls_and_serial_cycles() {
        use crate::emu::emulate;
        use crate::shuffle::{eliminate, ElimOpts};
        let b = crate::suite::by_name("tiledreduce").unwrap();
        let w = crate::suite::workload(&b, 4, 1, 1, 42);
        let emu = emulate(&w.kernel).unwrap();
        let opts = ElimOpts {
            enabled: true,
            block: w.cfg.block.0,
        };
        let (elim, report) = eliminate(&w.kernel, &w.kernel, &emu, opts);
        assert!(report.changed(), "pass must fire on tiledreduce: {report:?}");

        let mut cfg = w.cfg.clone();
        cfg.record_trace = true;
        let r0 = run(&w.kernel, &cfg, w.mem.clone()).unwrap();
        let r1 = run(&elim, &cfg, w.mem.clone()).unwrap();
        let m0 = model(&w.kernel, &r0.trace, &MAXWELL);
        let m1 = model(&elim, &r1.trace, &MAXWELL);
        let sync = Stall::Synchronization.index();
        assert!(m0.stalls[sync] > 0.0, "baseline must pay for its barriers");
        assert!(
            m1.stalls[sync] < m0.stalls[sync],
            "sync stalls must drop: {} -> {}",
            m0.stalls[sync],
            m1.stalls[sync]
        );
        assert!(
            m1.serial_cycles < m0.serial_cycles,
            "serial cycles must drop: {} -> {}",
            m0.serial_cycles,
            m1.serial_cycles
        );
    }

    #[test]
    fn memory_throttle_on_load_burst() {
        // 12 independent loads back-to-back exceed Kepler's outstanding budget
        let mut loads = String::new();
        let mut sums = String::new();
        for i in 0..12 {
            loads.push_str(&format!("ld.global.nc.f32 %f{}, [%rd5+{}];\n", i + 1, i * 128));
            if i > 0 {
                sums.push_str(&format!("add.f32 %f1, %f1, %f{};\n", i + 1));
            }
        }
        let src = format!(
            r#"
.visible .entry burst(.param .u64 out, .param .u64 a, .param .u32 n){{
.reg .b32 %r<6>; .reg .b64 %rd<6>; .reg .f32 %f<16>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd4, %r4, 4;
add.s64 %rd5, %rd3, %rd4;
{loads}{sums}cvta.to.global.u64 %rd3, %rd1;
add.s64 %rd5, %rd3, %rd4;
st.global.f32 [%rd5], %f1;
ret;
}}
"#
        );
        let (k, trace) = trace_of(&src, 32, 32);
        let rep = model(&k, &trace, &KEPLER);
        assert!(
            rep.stalls[Stall::MemThrottle.index()] > 0.0,
            "throttle expected: {:?}",
            rep.stalls
        );
    }
}
