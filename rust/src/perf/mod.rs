//! Performance model: per-architecture latency scoreboard + occupancy.

pub mod arch;
pub mod model;

pub use arch::{all as all_archs, by_name, Arch, KEPLER, MAXWELL, PASCAL, VOLTA};
pub use model::{model, PerfReport, Stall, STALL_KINDS};
