//! A minimal work-stealing task queue over `std::sync` (the offline crate
//! universe has no crossbeam).
//!
//! Layout: one global FIFO injector plus one deque per worker. A worker
//! pops its own deque LIFO (children it just spawned stay hot in cache),
//! then the injector FIFO, then steals FIFO from its siblings — stealing
//! the *oldest* task of a victim takes the coarsest-grained work, the
//! classic Cilk discipline. Tasks may spawn further tasks; termination is
//! by a pending-task count, not queue emptiness, so a worker never exits
//! while a running task could still publish work.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

#[derive(Debug)]
pub struct WorkQueue<T> {
    global: Mutex<VecDeque<T>>,
    locals: Vec<Mutex<VecDeque<T>>>,
    /// Tasks pushed and not yet retired (popped tasks stay pending until
    /// their execution — and any spawning — finished).
    pending: AtomicUsize,
    idle: Mutex<()>,
    wake: Condvar,
}

impl<T> WorkQueue<T> {
    pub fn new(workers: usize) -> WorkQueue<T> {
        WorkQueue {
            global: Mutex::new(VecDeque::new()),
            locals: (0..workers.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            idle: Mutex::new(()),
            wake: Condvar::new(),
        }
    }

    pub fn workers(&self) -> usize {
        self.locals.len()
    }

    /// Seed the global injector (callable from outside the pool).
    pub fn push(&self, t: T) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.global.lock().unwrap().push_back(t);
        self.wake.notify_one();
    }

    /// Push from worker `w`'s own deque (LIFO slot).
    pub fn push_local(&self, w: usize, t: T) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.locals[w].lock().unwrap().push_back(t);
        self.wake.notify_one();
    }

    /// Next task for worker `w`; blocks while work may still appear.
    /// Returns `None` once every pushed task has been retired.
    pub fn pop(&self, w: usize) -> Option<T> {
        loop {
            if let Some(t) = self.locals[w].lock().unwrap().pop_back() {
                return Some(t);
            }
            if let Some(t) = self.global.lock().unwrap().pop_front() {
                return Some(t);
            }
            for i in 1..self.locals.len() {
                let victim = (w + i) % self.locals.len();
                if let Some(t) = self.locals[victim].lock().unwrap().pop_front() {
                    return Some(t);
                }
            }
            if self.pending.load(Ordering::SeqCst) == 0 {
                return None;
            }
            // Nothing visible but tasks are still in flight: park briefly.
            // The timeout bounds the push→wait lost-wakeup window.
            let guard = self.idle.lock().unwrap();
            if self.pending.load(Ordering::SeqCst) == 0 {
                return None;
            }
            let _ = self.wake.wait_timeout(guard, Duration::from_millis(1)).unwrap();
        }
    }

    /// Retire one popped task. Must be called exactly once per `pop`,
    /// after the task ran (and pushed any children).
    pub fn retire(&self) {
        if self.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = self.idle.lock().unwrap();
            self.wake.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn spawning_tasks_all_execute() {
        // each seed task spawns `FANOUT` children; count every execution
        const SEEDS: usize = 7;
        const FANOUT: usize = 5;
        let q: WorkQueue<(bool, usize)> = WorkQueue::new(4);
        let ran = AtomicU64::new(0);
        for i in 0..SEEDS {
            q.push((true, i));
        }
        let (qr, ranr) = (&q, &ran);
        std::thread::scope(|s| {
            for w in 0..qr.workers() {
                s.spawn(move || {
                    while let Some((parent, _i)) = qr.pop(w) {
                        if parent {
                            for j in 0..FANOUT {
                                qr.push_local(w, (false, j));
                            }
                        }
                        ranr.fetch_add(1, Ordering::SeqCst);
                        qr.retire();
                    }
                });
            }
        });
        assert_eq!(ran.load(Ordering::SeqCst) as usize, SEEDS * (1 + FANOUT));
        assert_eq!(q.pending.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn single_worker_drains_in_order_free_of_deadlock() {
        let q: WorkQueue<u32> = WorkQueue::new(1);
        for i in 0..100 {
            q.push(i);
        }
        let mut seen = Vec::new();
        while let Some(t) = q.pop(0) {
            seen.push(t);
            q.retire();
        }
        assert_eq!(seen.len(), 100);
        // global injector is FIFO
        assert!(seen.windows(2).all(|w| w[0] < w[1]));
    }
}
