//! L3 coordinator: schedules the staged PTXASW pipeline over many kernels
//! on a work-stealing task pool.
//!
//! # Pipeline architecture
//!
//! Work is expressed against the [`crate::pipeline`] pass manager, whose
//! typed artifact chain is
//!
//! ```text
//! Parsed → Emulated → Detected → Synthesized → Validated → Scored
//! ```
//!
//! (plus two kernel-/workload-keyed side stages: `Workload` input
//! generation and the simulator's `Decoded` micro-op lowering).
//!
//! Every stage is content-addressed and cached in the pipeline's
//! [`crate::pipeline::ArtifactCache`]: the analysis stages by a stable
//! kernel hash, validation/scoring by that hash combined with the
//! [`crate::suite::WorkloadFingerprint`] of the simulator workload (which
//! is itself a cached stage, generated once per benchmark instead of once
//! per task). One emulation and one detection are computed per unique
//! kernel no matter how many synthesis variants, architectures, or
//! repeated suite runs consume them, and re-runs over the same pipeline —
//! or over a pipeline attached to the same on-disk store — skip
//! simulation too. Emulations share a single
//! [`crate::sym::SessionInterner`], so symbol/UF names are interned once
//! per session rather than once per kernel.
//!
//! # Scheduling
//!
//! A suite run is decomposed into (benchmark × variant × arch) tasks on a
//! [`queue::WorkQueue`] (global injector + per-worker deques with
//! stealing), rather than the old one-task-per-benchmark pool:
//!
//! * `Analyze(bench)` — generate/parse, emulate + detect (through the
//!   cache), simulate the baseline; spawns the per-variant tasks and the
//!   baseline's per-arch scoring tasks.
//! * `Variant(bench, variant)` — synthesize (cache), simulate, check
//!   bit-exactness against the baseline output; spawns per-arch scoring.
//! * `Score(bench, slot, arch)` — run the latency model for one kernel
//!   version on one architecture.
//!
//! Task-level parallelism here composes with the simulator's own
//! block-level parallelism (`Pipeline::with_sim_threads`, the CLI
//! `--sim-threads`); both are bit-deterministic, so any combination
//! yields identical results.
//!
//! Each benchmark's pieces are counted down; the task that retires the
//! last piece assembles the [`BenchResult`]. Results come back in input
//! order, identical to a serial run (verified by tests). Cache hit/miss
//! counters and per-stage wall time are exposed via
//! [`crate::pipeline::Pipeline::stats`] and rendered by
//! [`report::pipeline_stats`] (the CLI `--stats` flag).

pub mod queue;
pub mod report;

use crate::emu::EmuError;
use crate::perf::{Arch, PerfReport};
use crate::pipeline::{stages, Pipeline};
use crate::ptx::ast::Kernel;
use crate::ptx::printer::ContentHash;
use crate::shuffle::{DetectOpts, Detection, ElimOpts, Variant};
use crate::sim::{SimError, SimStats};
use crate::suite::{Benchmark, Pattern, WorkloadFingerprint};
use queue::WorkQueue;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub use crate::pipeline::PipelineStats;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub variants: Vec<Variant>,
    pub detect: DetectOpts,
    pub archs: Vec<&'static Arch>,
    pub threads: usize,
    /// Workload RNG seed (simulation sizes come from [`sim_sizes`]).
    pub seed: u64,
    /// Run the phase-liveness dead-store / barrier elimination pass after
    /// synthesis (`--no-elim` clears it). The per-benchmark block size is
    /// taken from the workload's launch config; the pass bails cleanly on
    /// anything it can't prove (multi-warp blocks, rewritten bodies).
    pub elim: bool,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            variants: vec![Variant::NoLoad, Variant::NoCorner, Variant::Full],
            detect: DetectOpts::default(),
            archs: crate::perf::all_archs().to_vec(),
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            seed: 42,
            elim: true,
        }
    }
}

/// Outcome of simulating + modelling one kernel version.
#[derive(Debug)]
pub struct RunOutcome {
    pub sim_stats: SimStats,
    /// One report per configured architecture (same order as `archs`).
    pub reports: Vec<PerfReport>,
    /// Output matched the baseline bit-exactly (None for the baseline).
    pub valid: Option<bool>,
}

/// Full pipeline result for one benchmark.
#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub lang: &'static str,
    pub detection: Detection,
    pub analysis_time: Duration,
    pub baseline: RunOutcome,
    pub variants: Vec<(Variant, RunOutcome)>,
    pub kernel: Kernel,
}

impl BenchResult {
    /// Figure 2 quantity: speed-up of a variant vs the original on arch `ai`.
    pub fn speedup(&self, variant: Variant, ai: usize) -> Option<f64> {
        let v = self.variants.iter().find(|(v, _)| *v == variant)?;
        Some(self.baseline.reports[ai].effective_cycles / v.1.reports[ai].effective_cycles)
    }
}

#[derive(Debug)]
pub enum PipelineError {
    Emu(String, EmuError),
    Sim(String, SimError),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::Emu(name, e) => write!(f, "{name}: emulation failed: {e}"),
            PipelineError::Sim(name, e) => write!(f, "{name}: simulation failed: {e}"),
        }
    }
}

impl std::error::Error for PipelineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PipelineError::Emu(_, e) => Some(e),
            PipelineError::Sim(_, e) => Some(e),
        }
    }
}

/// Simulation sizes per benchmark (small enough for CI, big enough to
/// exercise every warp/corner path).
pub fn sim_sizes(b: &Benchmark) -> (usize, usize, usize) {
    match &b.pattern {
        Pattern::MatMul { .. } => (48, 6, 8),
        Pattern::MatVec { .. } => (96, 1, 3),
        // nx = thread blocks (each `block` threads wide — multi-warp, so
        // the cooperative barrier scheduler is exercised across blocks)
        Pattern::TiledReduce { .. } => (6, 1, 1),
        Pattern::SharedStencil { .. } => (5, 1, 1),
        Pattern::SharedGather { .. } => (6, 1, 1),
        _ if b.dims == 3 => (40, 10, 8),
        _ => (96, 8, 1),
    }
}

/// Run the pipeline for one benchmark on a fresh (private) pipeline.
pub fn run_benchmark(b: &Benchmark, cfg: &PipelineConfig) -> Result<BenchResult, PipelineError> {
    run_benchmark_on(&Pipeline::new(), b, cfg)
}

/// Run one benchmark against a shared pipeline (cache reuse across calls).
pub fn run_benchmark_on(
    p: &Pipeline,
    b: &Benchmark,
    cfg: &PipelineConfig,
) -> Result<BenchResult, PipelineError> {
    run_suite_on(p, std::slice::from_ref(b), cfg)
        .pop()
        .expect("one result for one benchmark")
}

/// Run many benchmarks on a fresh pipeline; results in input order.
pub fn run_suite(
    benches: &[Benchmark],
    cfg: &PipelineConfig,
) -> Vec<Result<BenchResult, PipelineError>> {
    run_suite_on(&Pipeline::new(), benches, cfg)
}

/// Run many benchmarks against a shared pipeline on the work-stealing
/// pool; results come back in input order, bit-identical to a serial run.
pub fn run_suite_on(
    p: &Pipeline,
    benches: &[Benchmark],
    cfg: &PipelineConfig,
) -> Vec<Result<BenchResult, PipelineError>> {
    let nvar = cfg.variants.len();
    let narch = cfg.archs.len();
    // pieces per benchmark: analyze+baseline, baseline scores, and per
    // variant one simulation plus its scores
    let pieces = 1 + narch + nvar * (1 + narch);
    let workers = cfg.threads.max(1);

    let run = SuiteRun {
        p,
        cfg,
        benches,
        cells: benches
            .iter()
            .map(|_| BenchCell::new(nvar, narch, pieces))
            .collect(),
        results: Mutex::new((0..benches.len()).map(|_| None).collect()),
        queue: WorkQueue::new(workers),
    };
    for bi in 0..benches.len() {
        run.queue.push(Task::Analyze { bi });
    }
    let r = &run;
    std::thread::scope(|s| {
        for w in 0..r.queue.workers() {
            s.spawn(move || {
                while let Some(t) = r.queue.pop(w) {
                    r.exec(w, t);
                    r.queue.retire();
                }
            });
        }
    });
    run.results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("benchmark completed"))
        .collect()
}

/// One schedulable unit; `slot` 0 is the baseline, `1 + vi` a variant.
#[derive(Debug, Clone, Copy)]
enum Task {
    Analyze { bi: usize },
    Variant { bi: usize, vi: usize },
    Score { bi: usize, slot: usize, ai: usize },
}

/// Per-version assembly cell (baseline or one variant).
struct SlotCell {
    kernel: Mutex<Option<Arc<Kernel>>>,
    /// Content address of this version's kernel (keys `Validated`/`Scored`).
    hash: Mutex<Option<ContentHash>>,
    validated: Mutex<Option<Arc<stages::Validated>>>,
    reports: Mutex<Vec<Option<PerfReport>>>,
}

impl SlotCell {
    fn new(narch: usize) -> SlotCell {
        SlotCell {
            kernel: Mutex::new(None),
            hash: Mutex::new(None),
            validated: Mutex::new(None),
            reports: Mutex::new((0..narch).map(|_| None).collect()),
        }
    }
}

/// Per-benchmark assembly cell: tasks fill it, the last piece finalizes.
struct BenchCell {
    hash: Mutex<Option<ContentHash>>,
    /// Workload fingerprint shared by every version of this benchmark.
    wfp: Mutex<Option<WorkloadFingerprint>>,
    detection: Mutex<Option<Detection>>,
    analysis_time: Mutex<Duration>,
    /// `slots[0]` = baseline, `slots[1 + vi]` = variant `vi`.
    slots: Vec<SlotCell>,
    error: Mutex<Option<PipelineError>>,
    /// Total pieces this benchmark decomposes into — the single source of
    /// truth a failing analyze task retires wholesale.
    pieces: usize,
    remaining: AtomicUsize,
}

impl BenchCell {
    fn new(nvar: usize, narch: usize, pieces: usize) -> BenchCell {
        BenchCell {
            hash: Mutex::new(None),
            wfp: Mutex::new(None),
            detection: Mutex::new(None),
            analysis_time: Mutex::new(Duration::ZERO),
            slots: (0..1 + nvar).map(|_| SlotCell::new(narch)).collect(),
            error: Mutex::new(None),
            pieces,
            remaining: AtomicUsize::new(pieces),
        }
    }
}

type ResultCells = Mutex<Vec<Option<Result<BenchResult, PipelineError>>>>;

struct SuiteRun<'a> {
    p: &'a Pipeline,
    cfg: &'a PipelineConfig,
    benches: &'a [Benchmark],
    cells: Vec<BenchCell>,
    results: ResultCells,
    queue: WorkQueue<Task>,
}

impl SuiteRun<'_> {
    fn exec(&self, w: usize, task: Task) {
        match task {
            Task::Analyze { bi } => self.exec_analyze(w, bi),
            Task::Variant { bi, vi } => self.exec_variant(w, bi, vi),
            Task::Score { bi, slot, ai } => self.exec_score(bi, slot, ai),
        }
    }

    fn exec_analyze(&self, w: usize, bi: usize) {
        let b = &self.benches[bi];
        let cell = &self.cells[bi];
        let nvar = self.cfg.variants.len();
        let narch = self.cfg.archs.len();
        let all_pieces = cell.pieces;

        let parsed = self.p.intake(crate::suite::generate(b));
        let det = match self.p.detected_hashed(&parsed.kernel, parsed.hash, self.cfg.detect) {
            Ok(d) => d,
            Err(e) => {
                return self.fail(bi, all_pieces, PipelineError::Emu(b.name.into(), e));
            }
        };
        *cell.hash.lock().unwrap() = Some(parsed.hash);
        *cell.detection.lock().unwrap() = Some(det.detection.clone());
        *cell.analysis_time.lock().unwrap() = det.analysis_time();

        let wl = self.p.workload_art(b, sim_sizes(b), self.cfg.seed);
        *cell.wfp.lock().unwrap() = Some(wl.fingerprint);
        let v = match self.p.validated(&parsed.kernel, parsed.hash, &wl, None) {
            Ok(v) => v,
            Err(e) => {
                return self.fail(bi, all_pieces, PipelineError::Sim(b.name.into(), e));
            }
        };
        *cell.slots[0].kernel.lock().unwrap() = Some(parsed.kernel.clone());
        *cell.slots[0].hash.lock().unwrap() = Some(parsed.hash);
        *cell.slots[0].validated.lock().unwrap() = Some(v);

        for ai in 0..narch {
            self.queue.push_local(w, Task::Score { bi, slot: 0, ai });
        }
        for vi in 0..nvar {
            self.queue.push_local(w, Task::Variant { bi, vi });
        }
        self.retire_pieces(bi, 1);
    }

    fn exec_variant(&self, w: usize, bi: usize, vi: usize) {
        let b = &self.benches[bi];
        let cell = &self.cells[bi];
        let narch = self.cfg.archs.len();
        let variant = self.cfg.variants[vi];

        let kernel = cell.slots[0].kernel.lock().unwrap().clone().expect("baseline kernel set");
        let hash = cell.hash.lock().unwrap().expect("hash set");
        // served from the workload cache — generated once per benchmark;
        // its launch config supplies the block size the elimination pass
        // proves against
        let wl = self.p.workload_art(b, sim_sizes(b), self.cfg.seed);
        let elim = ElimOpts {
            enabled: self.cfg.elim,
            block: wl.workload.cfg.block.0,
        };
        // synthesis goes through the cache: the detection (and through it
        // the single emulation) is a guaranteed hit here
        let synth = match self
            .p
            .synthesized_hashed(&kernel, hash, self.cfg.detect, variant, elim)
        {
            Ok(s) => s,
            Err(e) => {
                return self.fail(bi, 1 + narch, PipelineError::Emu(b.name.into(), e));
            }
        };
        let baseline = cell.slots[0]
            .validated
            .lock()
            .unwrap()
            .clone()
            .expect("baseline simulated");
        let v = match self
            .p
            .validated(&synth.kernel, synth.hash, &wl, Some((hash, baseline.out.as_slice())))
        {
            Ok(v) => v,
            Err(e) => {
                return self.fail(bi, 1 + narch, PipelineError::Sim(b.name.into(), e));
            }
        };
        let slot = &cell.slots[1 + vi];
        *slot.kernel.lock().unwrap() = Some(synth.kernel.clone());
        *slot.hash.lock().unwrap() = Some(synth.hash);
        *slot.validated.lock().unwrap() = Some(v);
        for ai in 0..narch {
            self.queue.push_local(
                w,
                Task::Score {
                    bi,
                    slot: 1 + vi,
                    ai,
                },
            );
        }
        self.retire_pieces(bi, 1);
    }

    fn exec_score(&self, bi: usize, slot: usize, ai: usize) {
        let sc = &self.cells[bi].slots[slot];
        let kernel = sc.kernel.lock().unwrap().clone().expect("slot kernel set");
        let hash = sc.hash.lock().unwrap().expect("slot hash set");
        let wfp = self.cells[bi].wfp.lock().unwrap().expect("workload fingerprint set");
        let validated = sc.validated.lock().unwrap().clone().expect("slot simulated");
        let scored = self
            .p
            .scored(&kernel, hash, wfp, &validated, self.cfg.archs[ai]);
        sc.reports.lock().unwrap()[ai] = Some(scored.report.clone());
        self.retire_pieces(bi, 1);
    }

    /// Record the first error and retire the pieces the failed task owned
    /// (its own plus every child it will now never spawn).
    fn fail(&self, bi: usize, pieces: usize, err: PipelineError) {
        {
            let mut e = self.cells[bi].error.lock().unwrap();
            if e.is_none() {
                *e = Some(err);
            }
        }
        self.retire_pieces(bi, pieces);
    }

    fn retire_pieces(&self, bi: usize, n: usize) {
        if self.cells[bi].remaining.fetch_sub(n, Ordering::SeqCst) == n {
            self.finalize(bi);
        }
    }

    /// All pieces retired: assemble the [`BenchResult`] (or the error).
    fn finalize(&self, bi: usize) {
        let b = &self.benches[bi];
        let cell = &self.cells[bi];
        let res = if let Some(err) = cell.error.lock().unwrap().take() {
            Err(err)
        } else {
            let baseline = take_outcome(&cell.slots[0]);
            let variants = self
                .cfg
                .variants
                .iter()
                .enumerate()
                .map(|(vi, &v)| (v, take_outcome(&cell.slots[1 + vi])))
                .collect();
            let kernel = (*cell.slots[0]
                .kernel
                .lock()
                .unwrap()
                .clone()
                .expect("baseline kernel set"))
            .clone();
            Ok(BenchResult {
                name: b.name.to_string(),
                lang: b.lang.short(),
                detection: cell.detection.lock().unwrap().take().expect("detection set"),
                analysis_time: *cell.analysis_time.lock().unwrap(),
                baseline,
                variants,
                kernel,
            })
        };
        self.results.lock().unwrap()[bi] = Some(res);
    }
}

fn take_outcome(slot: &SlotCell) -> RunOutcome {
    let v = slot
        .validated
        .lock()
        .unwrap()
        .take()
        .expect("slot simulated");
    let reports = slot
        .reports
        .lock()
        .unwrap()
        .iter_mut()
        .map(|r| r.take().expect("slot scored"))
        .collect();
    RunOutcome {
        sim_stats: v.stats,
        reports,
        valid: v.valid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::by_name;

    #[test]
    fn pipeline_on_jacobi() {
        let b = by_name("jacobi").unwrap();
        let cfg = PipelineConfig::default();
        let r = run_benchmark(&b, &cfg).unwrap();
        assert_eq!(r.detection.shuffle_count(), 6);
        // Full must be valid, NoCorner invalid
        let full = r
            .variants
            .iter()
            .find(|(v, _)| *v == Variant::Full)
            .unwrap();
        assert_eq!(full.1.valid, Some(true));
        let nc = r
            .variants
            .iter()
            .find(|(v, _)| *v == Variant::NoCorner)
            .unwrap();
        assert_eq!(nc.1.valid, Some(false));
        // four arch reports each
        assert_eq!(r.baseline.reports.len(), 4);
        // speedups are defined and positive
        for ai in 0..4 {
            let s = r.speedup(Variant::Full, ai).unwrap();
            assert!(s > 0.0, "speedup {s}");
        }
    }

    /// The work-stealing pool must produce results identical to a serial
    /// run — same order, same detections, same validity, bit-identical
    /// modelled cycles.
    #[test]
    fn thread_pool_matches_serial() {
        let benches: Vec<_> = ["vecadd", "gradient", "jacobi"]
            .iter()
            .map(|n| by_name(n).unwrap())
            .collect();
        let serial_cfg = PipelineConfig {
            threads: 1,
            ..PipelineConfig::default()
        };
        let par_cfg = PipelineConfig {
            threads: 4,
            ..serial_cfg.clone()
        };

        let serial = run_suite(&benches, &serial_cfg);
        let parallel = run_suite(&benches, &par_cfg);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            let (s, p) = (s.as_ref().unwrap(), p.as_ref().unwrap());
            assert_eq!(s.name, p.name);
            assert_eq!(s.detection.chosen, p.detection.chosen);
            assert_eq!(s.detection.total_global_loads, p.detection.total_global_loads);
            assert_eq!(s.baseline.reports.len(), p.baseline.reports.len());
            for (sv, pv) in s.variants.iter().zip(&p.variants) {
                assert_eq!(sv.0, pv.0);
                assert_eq!(sv.1.valid, pv.1.valid);
                for (sr, pr) in sv.1.reports.iter().zip(&pv.1.reports) {
                    assert_eq!(
                        sr.effective_cycles.to_bits(),
                        pr.effective_cycles.to_bits(),
                        "{}: modelled cycles diverged between serial and parallel",
                        s.name
                    );
                }
            }
        }
        // original expectations
        assert_eq!(serial[0].as_ref().unwrap().detection.shuffle_count(), 0);
        assert_eq!(serial[1].as_ref().unwrap().detection.shuffle_count(), 1);
    }

    /// Acceptance: one emulation per unique kernel, ≥ 1 cache hit per
    /// synthesized variant, and a second suite run over the same pipeline
    /// is served entirely from the cache.
    #[test]
    fn suite_emulates_each_unique_kernel_once() {
        let benches: Vec<_> = ["vecadd", "gradient"]
            .iter()
            .map(|n| by_name(n).unwrap())
            .collect();
        let cfg = PipelineConfig::default();
        let nvar = cfg.variants.len() as u64;
        let p = Pipeline::new();

        let first = run_suite_on(&p, &benches, &cfg);
        assert!(first.iter().all(|r| r.is_ok()));
        let s1 = p.stats().cache;
        assert_eq!(s1.emulate_misses, 2, "one emulation per unique kernel");
        assert_eq!(s1.detect_misses, 2);
        assert!(
            s1.detect_hits >= nvar * 2,
            "each synthesized variant must hit the cached detection \
             (hits {}, want ≥ {})",
            s1.detect_hits,
            nvar * 2
        );

        let second = run_suite_on(&p, &benches, &cfg);
        let s2 = p.stats().cache;
        assert_eq!(s2.emulate_misses, 2, "re-runs must not re-emulate");
        assert_eq!(s2.synth_misses, s1.synth_misses, "re-runs must not re-synthesize");
        assert_eq!(
            s2.validate_misses, s1.validate_misses,
            "re-runs must not re-simulate"
        );
        assert_eq!(
            s2.workload_misses, s1.workload_misses,
            "re-runs must not regenerate workloads"
        );
        assert_eq!(s2.score_misses, s1.score_misses, "re-runs must not re-score");
        assert!(s2.validate_hits > s1.validate_hits);
        for (a, b) in first.iter().zip(&second) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.detection.chosen, b.detection.chosen);
        }
    }

    /// The workload stage is generated once per benchmark and shared by
    /// the baseline and all variants; validation is workload-keyed.
    #[test]
    fn workload_generated_once_per_benchmark() {
        let b = by_name("vecadd").unwrap();
        let cfg = PipelineConfig::default();
        let p = Pipeline::new();
        run_benchmark_on(&p, &b, &cfg).unwrap();
        let s = p.stats().cache;
        assert_eq!(s.workload_misses, 1, "one workload generation");
        // baseline + each variant re-resolved the cached workload
        assert_eq!(s.workload_hits as usize, cfg.variants.len());
        // baseline + variants each simulated exactly once
        assert_eq!(s.validate_misses as usize, 1 + cfg.variants.len());
        assert_eq!(s.validate_hits, 0);
    }
}
