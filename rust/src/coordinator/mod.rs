//! L3 coordinator: the full PTXASW pipeline over many kernels, fanned out
//! on a `std::thread` pool (the offline crate universe has no tokio; the
//! pipeline is CPU-bound anyway).
//!
//! Per kernel: generate/parse → symbolically emulate → detect → synthesize
//! every requested variant → validate on the warp simulator → score with
//! the per-architecture latency model. The result set carries everything
//! the Table 2 / Figure 2 / Figure 3 harnesses print.

pub mod report;

use crate::emu::{emulate, EmuError};
use crate::perf::{model, Arch, PerfReport};
use crate::ptx::ast::Kernel;
use crate::shuffle::{detect, synthesize, DetectOpts, Detection, Variant};
use crate::sim::{run, SimError, SimStats};
use crate::suite::{workload, Benchmark, Pattern};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub variants: Vec<Variant>,
    pub detect: DetectOpts,
    pub archs: Vec<&'static Arch>,
    pub threads: usize,
    /// Simulation sizes (nx, ny, nz) for 3D; 2D benchmarks use (nx, ny, 1).
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            variants: vec![Variant::NoLoad, Variant::NoCorner, Variant::Full],
            detect: DetectOpts::default(),
            archs: crate::perf::all_archs().to_vec(),
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            seed: 42,
        }
    }
}

/// Outcome of simulating + modelling one kernel version.
#[derive(Debug)]
pub struct RunOutcome {
    pub sim_stats: SimStats,
    /// One report per configured architecture (same order as `archs`).
    pub reports: Vec<PerfReport>,
    /// Output matched the baseline bit-exactly (None for the baseline).
    pub valid: Option<bool>,
}

/// Full pipeline result for one benchmark.
#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub lang: &'static str,
    pub detection: Detection,
    pub analysis_time: Duration,
    pub baseline: RunOutcome,
    pub variants: Vec<(Variant, RunOutcome)>,
    pub kernel: Kernel,
}

impl BenchResult {
    /// Figure 2 quantity: speed-up of a variant vs the original on arch `ai`.
    pub fn speedup(&self, variant: Variant, ai: usize) -> Option<f64> {
        let v = self.variants.iter().find(|(v, _)| *v == variant)?;
        Some(self.baseline.reports[ai].effective_cycles / v.1.reports[ai].effective_cycles)
    }
}

#[derive(Debug, thiserror::Error)]
pub enum PipelineError {
    #[error("{0}: emulation failed: {1}")]
    Emu(String, EmuError),
    #[error("{0}: simulation failed: {1}")]
    Sim(String, SimError),
}

/// Simulation sizes per benchmark (small enough for CI, big enough to
/// exercise every warp/corner path).
pub fn sim_sizes(b: &Benchmark) -> (usize, usize, usize) {
    match &b.pattern {
        Pattern::MatMul { .. } => (48, 6, 8),
        Pattern::MatVec { .. } => (96, 1, 3),
        _ if b.dims == 3 => (40, 10, 8),
        _ => (96, 8, 1),
    }
}

/// Run the pipeline for one benchmark.
pub fn run_benchmark(b: &Benchmark, cfg: &PipelineConfig) -> Result<BenchResult, PipelineError> {
    let kernel = crate::suite::generate(b);

    let t0 = Instant::now();
    let res = emulate(&kernel).map_err(|e| PipelineError::Emu(b.name.into(), e))?;
    let detection = detect(&kernel, &res, cfg.detect);
    let analysis_time = t0.elapsed();

    let (nx, ny, nz) = sim_sizes(b);
    let sim_one = |k: &Kernel| -> Result<(Vec<f32>, SimStats, Vec<PerfReport>), PipelineError> {
        let mut w = workload(b, nx, ny, nz, cfg.seed);
        w.cfg.record_trace = true;
        let r = run(k, &w.cfg, w.mem).map_err(|e| PipelineError::Sim(b.name.into(), e))?;
        let out = r
            .mem
            .read_f32s(w.out_ptr, w.out_len)
            .map_err(|e| PipelineError::Sim(b.name.into(), SimError::Mem(e)))?;
        let reports = cfg
            .archs
            .iter()
            .map(|a| model(k, &r.trace, a))
            .collect();
        Ok((out, r.stats, reports))
    };

    let (base_out, base_stats, base_reports) = sim_one(&kernel)?;
    let baseline = RunOutcome {
        sim_stats: base_stats,
        reports: base_reports,
        valid: None,
    };

    let mut variants = Vec::new();
    for &v in &cfg.variants {
        let sk = synthesize(&kernel, &detection, v);
        let (out, stats, reports) = sim_one(&sk)?;
        let valid = out
            .iter()
            .zip(&base_out)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        variants.push((
            v,
            RunOutcome {
                sim_stats: stats,
                reports,
                valid: Some(valid),
            },
        ));
    }

    Ok(BenchResult {
        name: b.name.to_string(),
        lang: b.lang.short(),
        detection,
        analysis_time,
        baseline,
        variants,
        kernel,
    })
}

/// Run many benchmarks on a thread pool; results come back in input order.
pub fn run_suite(
    benches: &[Benchmark],
    cfg: &PipelineConfig,
) -> Vec<Result<BenchResult, PipelineError>> {
    let next = Mutex::new(0usize);
    let results: Mutex<Vec<Option<Result<BenchResult, PipelineError>>>> =
        Mutex::new((0..benches.len()).map(|_| None).collect());

    std::thread::scope(|s| {
        for _ in 0..cfg.threads.max(1).min(benches.len().max(1)) {
            s.spawn(|| loop {
                let i = {
                    let mut n = next.lock().unwrap();
                    if *n >= benches.len() {
                        return;
                    }
                    let i = *n;
                    *n += 1;
                    i
                };
                let r = run_benchmark(&benches[i], cfg);
                results.lock().unwrap()[i] = Some(r);
            });
        }
    });

    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("worker completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::by_name;

    #[test]
    fn pipeline_on_jacobi() {
        let b = by_name("jacobi").unwrap();
        let cfg = PipelineConfig::default();
        let r = run_benchmark(&b, &cfg).unwrap();
        assert_eq!(r.detection.shuffle_count(), 6);
        // Full must be valid, NoCorner invalid
        let full = r
            .variants
            .iter()
            .find(|(v, _)| *v == Variant::Full)
            .unwrap();
        assert_eq!(full.1.valid, Some(true));
        let nc = r
            .variants
            .iter()
            .find(|(v, _)| *v == Variant::NoCorner)
            .unwrap();
        assert_eq!(nc.1.valid, Some(false));
        // four arch reports each
        assert_eq!(r.baseline.reports.len(), 4);
        // speedups are defined and positive
        for ai in 0..4 {
            let s = r.speedup(Variant::Full, ai).unwrap();
            assert!(s > 0.0, "speedup {s}");
        }
    }

    #[test]
    fn thread_pool_matches_serial() {
        let benches: Vec<_> = ["vecadd", "gradient"]
            .iter()
            .map(|n| by_name(n).unwrap())
            .collect();
        let mut cfg = PipelineConfig::default();
        cfg.threads = 2;
        let rs = run_suite(&benches, &cfg);
        assert_eq!(rs.len(), 2);
        let a = rs[0].as_ref().unwrap();
        let b = rs[1].as_ref().unwrap();
        assert_eq!(a.name, "vecadd");
        assert_eq!(b.name, "gradient");
        assert_eq!(a.detection.shuffle_count(), 0);
        assert_eq!(b.detection.shuffle_count(), 1);
    }
}
