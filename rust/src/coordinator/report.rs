//! Text renderers for the paper's tables and figures.

use super::BenchResult;
use crate::perf::{Arch, STALL_KINDS};
use crate::shuffle::Variant;
use std::fmt::Write;

/// Table 2: per-benchmark shuffle/load counts, average delta, analysis time.
pub fn table2(results: &[&BenchResult]) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "{:<12} {:>4} {:>13} {:>6} {:>10}",
        "name", "Lang", "Shuffle/Load", "Delta", "Analysis"
    )
    .unwrap();
    for r in results {
        let delta = r
            .detection
            .avg_delta()
            .map(|d| format!("{d:.2}"))
            .unwrap_or_else(|| "-".into());
        writeln!(
            s,
            "{:<12} {:>4} {:>6} / {:<4} {:>6} {:>9.3?}",
            r.name,
            r.lang,
            r.detection.shuffle_count(),
            r.detection.total_global_loads,
            delta,
            r.analysis_time,
        )
        .unwrap();
    }
    s
}

/// Figure 2: speed-up bars per architecture (text), plus occupancy.
pub fn figure2(results: &[&BenchResult], archs: &[&Arch], variants: &[Variant]) -> String {
    let mut s = String::new();
    for (ai, arch) in archs.iter().enumerate() {
        writeln!(s, "== {} ==", arch.name).unwrap();
        write!(s, "{:<12}", "benchmark").unwrap();
        for v in variants {
            write!(s, " {:>10}", v.name()).unwrap();
        }
        writeln!(s, " {:>6} {:>5}", "occ", "regs").unwrap();
        for r in results {
            write!(s, "{:<12}", r.name).unwrap();
            for v in variants {
                match r.speedup(*v, ai) {
                    Some(x) => write!(s, " {:>9.3}x", x).unwrap(),
                    None => write!(s, " {:>10}", "-").unwrap(),
                }
            }
            // occupancy/registers of the PTXASW variant (or baseline)
            let rep = r
                .variants
                .iter()
                .find(|(v, _)| *v == Variant::Full)
                .map(|(_, o)| &o.reports[ai])
                .unwrap_or(&r.baseline.reports[ai]);
            writeln!(s, " {:>5.2} {:>5}", rep.occupancy, rep.regs_per_thread).unwrap();
        }
    }
    s
}

/// Figure 3: stall-reason breakdown rows, Original then each variant.
pub fn figure3(r: &BenchResult, archs: &[&Arch]) -> String {
    let mut s = String::new();
    for (ai, arch) in archs.iter().enumerate() {
        writeln!(s, "-- {} / {} --", r.name, arch.name).unwrap();
        write!(s, "{:<10}", "version").unwrap();
        for k in STALL_KINDS {
            write!(s, " {:>12}", k.name()).unwrap();
        }
        writeln!(s).unwrap();
        let mut row = |label: &str, rep: &crate::perf::PerfReport| {
            write!(s, "{label:<10}").unwrap();
            for (_, f) in rep.stall_fractions() {
                write!(s, " {:>11.1}%", f * 100.0).unwrap();
            }
            writeln!(s).unwrap();
        };
        row("Original", &r.baseline.reports[ai]);
        for (v, o) in &r.variants {
            row(v.name(), &o.reports[ai]);
        }
    }
    s
}

/// `--stats` report: cache hit rates per artifact family (memory and
/// disk) and per-stage wall time for a pipeline session.
pub fn pipeline_stats(s: &crate::pipeline::PipelineStats) -> String {
    use crate::pipeline::STAGES;
    let mut out = String::new();
    writeln!(out, "== pipeline stats ==").unwrap();
    writeln!(
        out,
        "{:<12} {:>8} {:>8} {:>8} {:>9}",
        "artifact", "hits", "disk", "misses", "hit-rate"
    )
    .unwrap();
    let mut cache_row = |name: &str, hits: u64, disk: u64, misses: u64| {
        let total = hits + disk + misses;
        let rate = if total == 0 {
            0.0
        } else {
            (hits + disk) as f64 / total as f64
        };
        writeln!(
            out,
            "{name:<12} {hits:>8} {disk:>8} {misses:>8} {:>8.1}%",
            rate * 100.0
        )
        .unwrap();
    };
    cache_row("workload", s.cache.workload_hits, 0, s.cache.workload_misses);
    cache_row(
        "decoded",
        s.cache.decode_hits,
        s.cache.decode_disk_hits,
        s.cache.decode_misses,
    );
    cache_row(
        "emulated",
        s.cache.emulate_hits,
        s.cache.emulate_disk_hits,
        s.cache.emulate_misses,
    );
    cache_row(
        "detected",
        s.cache.detect_hits,
        s.cache.detect_disk_hits,
        s.cache.detect_misses,
    );
    cache_row(
        "synthesized",
        s.cache.synth_hits,
        s.cache.synth_disk_hits,
        s.cache.synth_misses,
    );
    cache_row(
        "validated",
        s.cache.validate_hits,
        s.cache.validate_disk_hits,
        s.cache.validate_misses,
    );
    cache_row(
        "scored",
        s.cache.score_hits,
        s.cache.score_disk_hits,
        s.cache.score_misses,
    );
    writeln!(
        out,
        "overall hit rate: {:.1}% ({} hits / {} disk / {} misses)",
        s.cache.hit_rate() * 100.0,
        s.cache.hits(),
        s.cache.disk_hits(),
        s.cache.misses()
    )
    .unwrap();
    if s.disk.enabled {
        writeln!(
            out,
            "disk cache: {} hits, {} misses, {} stores, {} evictions, {} corrupt \
             (resident {} bytes)",
            s.disk.hits,
            s.disk.misses,
            s.disk.stores,
            s.disk.evictions,
            s.disk.corrupt,
            s.disk.resident_bytes
        )
        .unwrap();
    } else {
        writeln!(out, "disk cache: disabled").unwrap();
    }
    writeln!(
        out,
        "engine: {} superblocks entered, {} vector warp steps",
        s.superblocks_entered, s.vector_warp_steps
    )
    .unwrap();
    writeln!(out).unwrap();
    writeln!(out, "{:<12} {:>8} {:>12} {:>12}", "stage", "runs", "total", "mean").unwrap();
    for stage in STAGES {
        let runs = s.stage_count(stage);
        let total = s.stage_time(stage);
        let mean = if runs == 0 {
            std::time::Duration::ZERO
        } else {
            total / runs as u32
        };
        writeln!(
            out,
            "{:<12} {:>8} {:>11.3?} {:>11.3?}",
            stage.name(),
            runs,
            total,
            mean
        )
        .unwrap();
    }
    writeln!(out).unwrap();
    out.push_str(&crate::pipeline::metrics_snapshot(s).render_table());
    out
}

/// One-line summary per benchmark/arch for logs.
pub fn summary_line(r: &BenchResult, ai: usize) -> String {
    let f = r.speedup(Variant::Full, ai).unwrap_or(1.0);
    format!(
        "{:<12} shfl {:>2}/{:<3} full {:.3}x occ {:.2}",
        r.name,
        r.detection.shuffle_count(),
        r.detection.total_global_loads,
        f,
        r.baseline.reports[ai].occupancy
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_benchmark, PipelineConfig};
    use crate::suite::by_name;

    #[test]
    fn renders_all_reports() {
        let b = by_name("gradient").unwrap();
        let cfg = PipelineConfig::default();
        let r = run_benchmark(&b, &cfg).unwrap();
        let refs = [&r];
        let t2 = table2(&refs);
        assert!(t2.contains("gradient"));
        assert!(t2.contains("1 / 6"));
        let f2 = figure2(&refs, &cfg.archs, &cfg.variants);
        assert!(f2.contains("Kepler") && f2.contains("Volta"));
        let f3 = figure3(&r, &cfg.archs);
        assert!(f3.contains("mem_dep"));
        assert!(!summary_line(&r, 0).is_empty());
    }

    #[test]
    fn renders_pipeline_stats() {
        let p = crate::pipeline::Pipeline::new();
        let b = by_name("vecadd").unwrap();
        let cfg = PipelineConfig::default();
        crate::coordinator::run_benchmark_on(&p, &b, &cfg).unwrap();
        let s = p.stats();
        let text = pipeline_stats(&s);
        assert!(text.contains("emulated"));
        assert!(text.contains("synthesize"));
        assert!(text.contains("hit-rate"));
        assert!(text.contains("workload"));
        assert!(text.contains("decoded"));
        assert!(text.contains("decode"));
        assert!(text.contains("validated"));
        assert!(text.contains("scored"));
        assert!(text.contains("disk cache: disabled"));
        // the suite ran, so emulate/decode/validate/score all have runs
        assert!(s.stage_count(crate::pipeline::Stage::Emulate) >= 1);
        assert!(s.stage_count(crate::pipeline::Stage::Decode) >= 1);
        assert!(s.stage_count(crate::pipeline::Stage::Validate) >= 1);
        assert!(s.stage_count(crate::pipeline::Stage::Score) >= 1);
    }
}
