//! Abstract syntax tree for the PTX subset PTXASW understands.
//!
//! The subset covers everything the NVHPC OpenACC code generator emits for
//! the KernelGen benchmarks (Listing 2 of the paper) plus the instructions
//! PTXASW itself synthesizes (`shfl.sync`, `activemask`, predicate logic).

use std::fmt;

/// Scalar PTX types (`.u32`, `.f32`, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Type {
    U8,
    U16,
    U32,
    U64,
    S8,
    S16,
    S32,
    S64,
    B8,
    B16,
    B32,
    B64,
    F32,
    F64,
    Pred,
}

impl Type {
    /// Width in bits. Predicates are modelled as 1 bit.
    pub fn bits(self) -> u32 {
        match self {
            Type::U8 | Type::S8 | Type::B8 => 8,
            Type::U16 | Type::S16 | Type::B16 => 16,
            Type::U32 | Type::S32 | Type::B32 | Type::F32 => 32,
            Type::U64 | Type::S64 | Type::B64 | Type::F64 => 64,
            Type::Pred => 1,
        }
    }

    pub fn bytes(self) -> u64 {
        (self.bits() as u64 + 7) / 8
    }

    pub fn is_signed(self) -> bool {
        matches!(self, Type::S8 | Type::S16 | Type::S32 | Type::S64)
    }

    pub fn is_float(self) -> bool {
        matches!(self, Type::F32 | Type::F64)
    }

    /// Parse a type suffix without the leading dot (e.g. `"u32"`).
    pub fn from_suffix(s: &str) -> Option<Type> {
        Some(match s {
            "u8" => Type::U8,
            "u16" => Type::U16,
            "u32" => Type::U32,
            "u64" => Type::U64,
            "s8" => Type::S8,
            "s16" => Type::S16,
            "s32" => Type::S32,
            "s64" => Type::S64,
            "b8" => Type::B8,
            "b16" => Type::B16,
            "b32" => Type::B32,
            "b64" => Type::B64,
            "f32" => Type::F32,
            "f64" => Type::F64,
            "pred" => Type::Pred,
            _ => return None,
        })
    }

    pub fn suffix(self) -> &'static str {
        match self {
            Type::U8 => "u8",
            Type::U16 => "u16",
            Type::U32 => "u32",
            Type::U64 => "u64",
            Type::S8 => "s8",
            Type::S16 => "s16",
            Type::S32 => "s32",
            Type::S64 => "s64",
            Type::B8 => "b8",
            Type::B16 => "b16",
            Type::B32 => "b32",
            Type::B64 => "b64",
            Type::F32 => "f32",
            Type::F64 => "f64",
            Type::Pred => "pred",
        }
    }
}

impl fmt::Display for Type {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, ".{}", self.suffix())
    }
}

/// PTX state spaces relevant to the emulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Space {
    Param,
    Global,
    Shared,
    Local,
    Const,
}

impl Space {
    pub fn suffix(self) -> &'static str {
        match self {
            Space::Param => "param",
            Space::Global => "global",
            Space::Shared => "shared",
            Space::Local => "local",
            Space::Const => "const",
        }
    }
}

/// A virtual register name, e.g. `%rd7`. Interned per-kernel by the
/// emulator; the AST keeps the textual name for round-tripping.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Reg(pub String);

impl Reg {
    pub fn new(s: impl Into<String>) -> Reg {
        Reg(s.into())
    }
}

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Special (pre-defined, read-only) registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Special {
    TidX,
    TidY,
    TidZ,
    NtidX,
    NtidY,
    NtidZ,
    CtaidX,
    CtaidY,
    CtaidZ,
    NctaidX,
    NctaidY,
    NctaidZ,
    LaneId,
    WarpSize,
}

impl Special {
    pub fn name(self) -> &'static str {
        match self {
            Special::TidX => "%tid.x",
            Special::TidY => "%tid.y",
            Special::TidZ => "%tid.z",
            Special::NtidX => "%ntid.x",
            Special::NtidY => "%ntid.y",
            Special::NtidZ => "%ntid.z",
            Special::CtaidX => "%ctaid.x",
            Special::CtaidY => "%ctaid.y",
            Special::CtaidZ => "%ctaid.z",
            Special::NctaidX => "%nctaid.x",
            Special::NctaidY => "%nctaid.y",
            Special::NctaidZ => "%nctaid.z",
            Special::LaneId => "%laneid",
            Special::WarpSize => "WARP_SZ",
        }
    }

    pub fn from_name(s: &str) -> Option<Special> {
        Some(match s {
            "%tid.x" => Special::TidX,
            "%tid.y" => Special::TidY,
            "%tid.z" => Special::TidZ,
            "%ntid.x" => Special::NtidX,
            "%ntid.y" => Special::NtidY,
            "%ntid.z" => Special::NtidZ,
            "%ctaid.x" => Special::CtaidX,
            "%ctaid.y" => Special::CtaidY,
            "%ctaid.z" => Special::CtaidZ,
            "%nctaid.x" => Special::NctaidX,
            "%nctaid.y" => Special::NctaidY,
            "%nctaid.z" => Special::NctaidZ,
            "%laneid" => Special::LaneId,
            "WARP_SZ" => Special::WarpSize,
            _ => return None,
        })
    }
}

/// An instruction operand.
#[derive(Debug, Clone, PartialEq)]
pub enum Operand {
    Reg(Reg),
    /// Integer immediate (sign carried in the i128 so `-1` on u64 works).
    ImmInt(i128),
    /// `0f3F800000`-style f32 immediate, stored as raw bits.
    ImmF32(u32),
    /// `0dXXXXXXXXXXXXXXXX`-style f64 immediate, stored as raw bits.
    ImmF64(u64),
    Special(Special),
    /// A kernel parameter or shared-variable name used as an address base.
    Var(String),
}

impl Operand {
    pub fn reg(s: &str) -> Operand {
        Operand::Reg(Reg::new(s))
    }
    pub fn as_reg(&self) -> Option<&Reg> {
        match self {
            Operand::Reg(r) => Some(r),
            _ => None,
        }
    }
}

/// `[base+offset]` memory operand.
#[derive(Debug, Clone, PartialEq)]
pub struct Address {
    pub base: Operand,
    pub offset: i64,
}

/// Integer binary ops (also used for predicate logic with `Type::Pred`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntBinOp {
    Add,
    Sub,
    MulLo,
    MulHi,
    MulWide,
    Div,
    Rem,
    Min,
    Max,
    And,
    Or,
    Xor,
    Shl,
    Shr,
}

impl IntBinOp {
    pub fn mnemonic(self) -> &'static str {
        match self {
            IntBinOp::Add => "add",
            IntBinOp::Sub => "sub",
            IntBinOp::MulLo => "mul.lo",
            IntBinOp::MulHi => "mul.hi",
            IntBinOp::MulWide => "mul.wide",
            IntBinOp::Div => "div",
            IntBinOp::Rem => "rem",
            IntBinOp::Min => "min",
            IntBinOp::Max => "max",
            IntBinOp::And => "and",
            IntBinOp::Or => "or",
            IntBinOp::Xor => "xor",
            IntBinOp::Shl => "shl",
            IntBinOp::Shr => "shr",
        }
    }
}

/// Floating-point binary ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FltBinOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

impl FltBinOp {
    pub fn mnemonic(self) -> &'static str {
        match self {
            FltBinOp::Add => "add",
            FltBinOp::Sub => "sub",
            FltBinOp::Mul => "mul",
            FltBinOp::Div => "div.rn",
            FltBinOp::Min => "min",
            FltBinOp::Max => "max",
        }
    }
}

/// Floating-point unary ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FltUnOp {
    Neg,
    Abs,
    Sqrt,
    Rsqrt,
    Rcp,
    Sin,
    Cos,
    Ex2,
    Lg2,
}

impl FltUnOp {
    pub fn mnemonic(self) -> &'static str {
        match self {
            FltUnOp::Neg => "neg",
            FltUnOp::Abs => "abs",
            FltUnOp::Sqrt => "sqrt.rn",
            FltUnOp::Rsqrt => "rsqrt.approx",
            FltUnOp::Rcp => "rcp.rn",
            FltUnOp::Sin => "sin.approx",
            FltUnOp::Cos => "cos.approx",
            FltUnOp::Ex2 => "ex2.approx",
            FltUnOp::Lg2 => "lg2.approx",
        }
    }
}

/// Comparison predicates for `setp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    pub fn mnemonic(self) -> &'static str {
        match self {
            CmpOp::Eq => "eq",
            CmpOp::Ne => "ne",
            CmpOp::Lt => "lt",
            CmpOp::Le => "le",
            CmpOp::Gt => "gt",
            CmpOp::Ge => "ge",
        }
    }

    pub fn from_suffix(s: &str) -> Option<CmpOp> {
        Some(match s {
            "eq" => CmpOp::Eq,
            "ne" => CmpOp::Ne,
            "lt" => CmpOp::Lt,
            "le" => CmpOp::Le,
            "gt" => CmpOp::Gt,
            "ge" => CmpOp::Ge,
            _ => return None,
        })
    }

    pub fn negate(self) -> CmpOp {
        match self {
            CmpOp::Eq => CmpOp::Ne,
            CmpOp::Ne => CmpOp::Eq,
            CmpOp::Lt => CmpOp::Ge,
            CmpOp::Le => CmpOp::Gt,
            CmpOp::Gt => CmpOp::Le,
            CmpOp::Ge => CmpOp::Lt,
        }
    }
}

/// Shuffle modes of `shfl.sync`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShflMode {
    Up,
    Down,
    Bfly,
    Idx,
}

impl ShflMode {
    pub fn suffix(self) -> &'static str {
        match self {
            ShflMode::Up => "up",
            ShflMode::Down => "down",
            ShflMode::Bfly => "bfly",
            ShflMode::Idx => "idx",
        }
    }
}

/// One PTX instruction, without its guard predicate.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// `ld.<space>[.nc].<ty> dst, [addr];`
    Ld {
        space: Space,
        nc: bool,
        ty: Type,
        dst: Reg,
        addr: Address,
    },
    /// `st.<space>.<ty> [addr], src;`
    St {
        space: Space,
        ty: Type,
        addr: Address,
        src: Operand,
    },
    /// `mov.<ty> dst, src;`
    Mov { ty: Type, dst: Reg, src: Operand },
    /// `cvta[.to.global].u64 dst, src;`
    Cvta {
        to_global: bool,
        dst: Reg,
        src: Operand,
    },
    /// Integer/bitwise/predicate-logic binary op.
    IntBin {
        op: IntBinOp,
        ty: Type,
        dst: Reg,
        a: Operand,
        b: Operand,
    },
    /// `mad.lo.<ty>` / `mad.wide.<ty>` : dst = a*b + c.
    Mad {
        wide: bool,
        ty: Type,
        dst: Reg,
        a: Operand,
        b: Operand,
        c: Operand,
    },
    /// `not.<ty> dst, a;` (bitwise / predicate negation)
    Not { ty: Type, dst: Reg, a: Operand },
    /// `neg.<ty> dst, a;` (integer negate)
    Neg { ty: Type, dst: Reg, a: Operand },
    /// Float binary op.
    FltBin {
        op: FltBinOp,
        ty: Type,
        dst: Reg,
        a: Operand,
        b: Operand,
    },
    /// `fma.rn.<ty> dst, a, b, c;`
    Fma {
        ty: Type,
        dst: Reg,
        a: Operand,
        b: Operand,
        c: Operand,
    },
    /// Float unary op.
    FltUn {
        op: FltUnOp,
        ty: Type,
        dst: Reg,
        a: Operand,
    },
    /// `setp.<cmp>.<ty> p, a, b;`
    Setp {
        cmp: CmpOp,
        ty: Type,
        dst: Reg,
        a: Operand,
        b: Operand,
    },
    /// `selp.<ty> dst, a, b, p;`
    Selp {
        ty: Type,
        dst: Reg,
        a: Operand,
        b: Operand,
        p: Operand,
    },
    /// `cvt[.rni?][.dty.sty] dst, src;`
    Cvt {
        dty: Type,
        sty: Type,
        dst: Reg,
        src: Operand,
    },
    /// `bra[.uni] target;`
    Bra { uni: bool, target: String },
    /// `shfl.sync.<mode>.b32 dst[|p], src, b, c, mask;`
    Shfl {
        mode: ShflMode,
        dst: Reg,
        pred_out: Option<Reg>,
        src: Operand,
        b: Operand,
        c: Operand,
        mask: Operand,
    },
    /// `activemask.b32 dst;`
    Activemask { dst: Reg },
    /// `bar.sync id [, cnt];` — block-wide barrier. `cnt` is the optional
    /// participating-thread count; the simulator accepts it only when it
    /// names the launched block exactly (partial-block barriers are out of
    /// scope for the cooperative scheduler).
    BarSync { id: u32, cnt: Option<u32> },
    /// `ret;`
    Ret,
    /// `exit;` (alias of ret for kernels)
    Exit,
}

/// Guard predicate: `@%p` or `@!%p`.
#[derive(Debug, Clone, PartialEq)]
pub struct Guard {
    pub reg: Reg,
    pub negated: bool,
}

/// A body statement: label or (possibly guarded) instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Statement {
    Label(String),
    Instr { guard: Option<Guard>, op: Op },
}

impl Statement {
    pub fn instr(op: Op) -> Statement {
        Statement::Instr { guard: None, op }
    }
    pub fn guarded(reg: &str, negated: bool, op: Op) -> Statement {
        Statement::Instr {
            guard: Some(Guard {
                reg: Reg::new(reg),
                negated,
            }),
            op,
        }
    }
}

/// `.reg .f32 %f<4>;`
#[derive(Debug, Clone, PartialEq)]
pub struct RegDecl {
    pub ty: Type,
    pub prefix: String,
    pub count: u32,
}

/// `.shared .align A .b8 name[bytes];`
#[derive(Debug, Clone, PartialEq)]
pub struct SharedDecl {
    pub name: String,
    pub align: u32,
    pub bytes: u64,
}

/// `.param .u64 name`
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub ty: Type,
    pub name: String,
}

/// A `.entry` kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub params: Vec<Param>,
    pub regs: Vec<RegDecl>,
    pub shared: Vec<SharedDecl>,
    pub body: Vec<Statement>,
}

impl Kernel {
    /// Count of declared registers (proxy the paper uses for occupancy).
    pub fn declared_regs(&self) -> u32 {
        self.regs.iter().map(|r| r.count).sum()
    }

    /// Number of global-memory load instructions in the body.
    pub fn global_loads(&self) -> usize {
        self.body
            .iter()
            .filter(|s| {
                matches!(
                    s,
                    Statement::Instr {
                        op: Op::Ld {
                            space: Space::Global,
                            ..
                        },
                        ..
                    }
                )
            })
            .count()
    }

    /// Number of `shfl.sync` instructions in the body.
    pub fn shuffles(&self) -> usize {
        self.body
            .iter()
            .filter(|s| matches!(s, Statement::Instr { op: Op::Shfl { .. }, .. }))
            .count()
    }
}

/// A PTX module (translation unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Module {
    pub version: (u32, u32),
    pub target: String,
    pub address_size: u32,
    pub kernels: Vec<Kernel>,
}

impl Module {
    pub fn kernel(&self, name: &str) -> Option<&Kernel> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// Wrap one kernel in a minimal module (the printer needs the
    /// module-level directives).
    pub fn single(kernel: Kernel) -> Module {
        Module {
            version: (7, 6),
            target: "sm_70".to_string(),
            address_size: 64,
            kernels: vec![kernel],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_widths() {
        assert_eq!(Type::U8.bits(), 8);
        assert_eq!(Type::F32.bits(), 32);
        assert_eq!(Type::B64.bits(), 64);
        assert_eq!(Type::Pred.bits(), 1);
        assert_eq!(Type::F64.bytes(), 8);
    }

    #[test]
    fn type_suffix_roundtrip() {
        for t in [
            Type::U8,
            Type::U16,
            Type::U32,
            Type::U64,
            Type::S8,
            Type::S16,
            Type::S32,
            Type::S64,
            Type::B8,
            Type::B16,
            Type::B32,
            Type::B64,
            Type::F32,
            Type::F64,
            Type::Pred,
        ] {
            assert_eq!(Type::from_suffix(t.suffix()), Some(t));
        }
        assert_eq!(Type::from_suffix("v4"), None);
    }

    #[test]
    fn special_roundtrip() {
        for s in [
            Special::TidX,
            Special::NtidY,
            Special::CtaidZ,
            Special::NctaidX,
            Special::LaneId,
        ] {
            assert_eq!(Special::from_name(s.name()), Some(s));
        }
    }

    #[test]
    fn cmp_negation_involutive() {
        for c in [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge] {
            assert_eq!(c.negate().negate(), c);
        }
    }

    #[test]
    fn kernel_counters() {
        let k = Kernel {
            name: "k".into(),
            params: vec![],
            regs: vec![
                RegDecl {
                    ty: Type::F32,
                    prefix: "%f".into(),
                    count: 4,
                },
                RegDecl {
                    ty: Type::B64,
                    prefix: "%rd".into(),
                    count: 3,
                },
            ],
            shared: vec![],
            body: vec![
                Statement::instr(Op::Ld {
                    space: Space::Global,
                    nc: true,
                    ty: Type::F32,
                    dst: Reg::new("%f1"),
                    addr: Address {
                        base: Operand::reg("%rd1"),
                        offset: 4,
                    },
                }),
                Statement::instr(Op::Ret),
            ],
        };
        assert_eq!(k.declared_regs(), 7);
        assert_eq!(k.global_loads(), 1);
        assert_eq!(k.shuffles(), 0);
    }
}
