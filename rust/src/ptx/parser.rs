//! Recursive-descent parser for the PTX subset.
//!
//! Accepts the module layout NVHPC/nvcc emit (Listing 2 of the paper):
//! `.version/.target/.address_size` header, `.visible .entry` kernels with
//! `.param` lists, `.reg`/`.shared` declarations, labels, guarded
//! instructions. Unknown module-level directives are skipped; unknown
//! instructions are an error (the emulator must understand every opcode it
//! runs).

use super::ast::*;
use super::lexer::{lex, Spanned, Tok};

#[derive(Debug)]
pub struct ParseError {
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(src: &str) -> Result<Module, ParseError> {
    let toks = lex(src).map_err(|e| ParseError {
        line: e.line,
        msg: e.msg,
    })?;
    Parser { toks, pos: 0 }.module()
}

/// Parse a source string that contains exactly one kernel.
pub fn parse_kernel(src: &str) -> Result<Kernel, ParseError> {
    let m = parse(src)?;
    m.kernels.into_iter().next().ok_or(ParseError {
        line: 0,
        msg: "no kernel in module".into(),
    })
}

struct Parser {
    toks: Vec<Spanned>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|s| &s.tok)
    }

    fn line(&self) -> u32 {
        self.toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|s| s.line)
            .unwrap_or(0)
    }

    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError {
            line: self.line(),
            msg: msg.into(),
        }
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|s| s.tok.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, t: &Tok) -> Result<(), ParseError> {
        match self.next() {
            Some(ref got) if got == t => Ok(()),
            Some(got) => Err(self.err(format!("expected `{t}`, got `{got}`"))),
            None => Err(self.err(format!("expected `{t}`, got end of input"))),
        }
    }

    fn eat(&mut self, t: &Tok) -> bool {
        if self.peek() == Some(t) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn word(&mut self) -> Result<String, ParseError> {
        match self.next() {
            Some(Tok::Word(w)) => Ok(w),
            Some(got) => Err(self.err(format!("expected word, got `{got}`"))),
            None => Err(self.err("expected word, got end of input")),
        }
    }

    fn int(&mut self) -> Result<i128, ParseError> {
        let neg = self.eat(&Tok::Minus);
        match self.next() {
            Some(Tok::Int(v)) => Ok(if neg { -v } else { v }),
            Some(got) => Err(self.err(format!("expected integer, got `{got}`"))),
            None => Err(self.err("expected integer, got end of input")),
        }
    }

    fn module(&mut self) -> Result<Module, ParseError> {
        let mut version = (7, 6);
        let mut target = "sm_70".to_string();
        let mut address_size = 64;
        let mut kernels = Vec::new();

        while let Some(tok) = self.peek().cloned() {
            match tok {
                Tok::Word(w) if w == ".version" => {
                    self.pos += 1;
                    let major = self.int()? as u32;
                    // minor arrives as a `.N` word because of dot-words
                    let minor = match self.peek() {
                        Some(Tok::Word(m)) if m.starts_with('.') => {
                            let v = m[1..].parse::<u32>().unwrap_or(0);
                            self.pos += 1;
                            v
                        }
                        _ => 0,
                    };
                    version = (major, minor);
                }
                Tok::Word(w) if w == ".target" => {
                    self.pos += 1;
                    target = self.word()?;
                    // skip `, texmode_independent` style tails
                    while self.eat(&Tok::Comma) {
                        self.word()?;
                    }
                }
                Tok::Word(w) if w == ".address_size" => {
                    self.pos += 1;
                    address_size = self.int()? as u32;
                }
                Tok::Word(w) if w == ".visible" || w == ".entry" || w == ".weak" => {
                    kernels.push(self.kernel()?);
                }
                Tok::Word(w) if w.starts_with('.') => {
                    // Unknown module directive (.file, .extern, ...): skip to `;`
                    // or skip a braced body.
                    self.pos += 1;
                    self.skip_directive()?;
                }
                _ => return Err(self.err(format!("unexpected token `{tok}` at module level"))),
            }
        }

        Ok(Module {
            version,
            target,
            address_size,
            kernels,
        })
    }

    fn skip_directive(&mut self) -> Result<(), ParseError> {
        let mut depth = 0usize;
        while let Some(t) = self.peek() {
            match t {
                Tok::LBrace => depth += 1,
                Tok::RBrace => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        self.pos += 1;
                        return Ok(());
                    }
                }
                Tok::Semi if depth == 0 => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => {}
            }
            self.pos += 1;
        }
        Ok(())
    }

    fn kernel(&mut self) -> Result<Kernel, ParseError> {
        // .visible? .entry name ( params ) { body }
        loop {
            match self.peek() {
                Some(Tok::Word(w)) if w == ".visible" || w == ".weak" => {
                    self.pos += 1;
                }
                Some(Tok::Word(w)) if w == ".entry" => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(self.err("expected `.entry`")),
            }
        }
        let name = self.word()?;
        let mut params = Vec::new();
        if self.eat(&Tok::LParen) {
            while !self.eat(&Tok::RParen) {
                let d = self.word()?;
                if d != ".param" {
                    return Err(self.err(format!("expected `.param`, got `{d}`")));
                }
                let ty_word = self.word()?;
                let ty = Type::from_suffix(ty_word.trim_start_matches('.'))
                    .ok_or_else(|| self.err(format!("bad param type `{ty_word}`")))?;
                // optional .ptr / .global / .align N decorations
                let pname;
                loop {
                    let w = self.word()?;
                    if w == ".ptr" || w == ".global" {
                        continue;
                    }
                    if w == ".align" {
                        self.int()?;
                        continue;
                    }
                    pname = w;
                    break;
                }
                params.push(Param { ty, name: pname });
                self.eat(&Tok::Comma);
            }
        }
        // skip performance tuning directives before `{`
        while let Some(Tok::Word(w)) = self.peek() {
            if w.starts_with('.') {
                let _ = self.word()?;
                // their arguments are ints/commas until `{`
                while matches!(self.peek(), Some(Tok::Int(_)) | Some(Tok::Comma)) {
                    self.pos += 1;
                }
            } else {
                break;
            }
        }
        self.expect(&Tok::LBrace)?;

        let mut regs = Vec::new();
        let mut shared = Vec::new();
        let mut body = Vec::new();

        loop {
            match self.peek().cloned() {
                None => return Err(self.err("unterminated kernel body")),
                Some(Tok::RBrace) => {
                    self.pos += 1;
                    break;
                }
                Some(Tok::Word(w)) if w == ".reg" => {
                    self.pos += 1;
                    let ty_word = self.word()?;
                    let ty = Type::from_suffix(ty_word.trim_start_matches('.'))
                        .ok_or_else(|| self.err(format!("bad reg type `{ty_word}`")))?;
                    let prefix = self.word()?;
                    self.expect(&Tok::Lt)?;
                    let count = self.int()? as u32;
                    self.expect(&Tok::Gt)?;
                    self.expect(&Tok::Semi)?;
                    regs.push(RegDecl { ty, prefix, count });
                }
                Some(Tok::Word(w)) if w == ".shared" => {
                    self.pos += 1;
                    let mut align = 4;
                    let mut w2 = self.word()?;
                    if w2 == ".align" {
                        align = self.int()? as u32;
                        w2 = self.word()?;
                    }
                    // w2 is the element type (.b8 usually); name follows
                    if Type::from_suffix(w2.trim_start_matches('.')).is_none() {
                        return Err(self.err(format!("bad shared decl type `{w2}`")));
                    }
                    let name = self.word()?;
                    self.expect(&Tok::LBracket)?;
                    let bytes = self.int()? as u64;
                    self.expect(&Tok::RBracket)?;
                    self.expect(&Tok::Semi)?;
                    shared.push(SharedDecl { name, align, bytes });
                }
                Some(Tok::At) => {
                    self.pos += 1;
                    let negated = self.eat(&Tok::Bang);
                    let reg = self.word()?;
                    let op = self.instruction()?;
                    body.push(Statement::Instr {
                        guard: Some(Guard {
                            reg: Reg::new(reg),
                            negated,
                        }),
                        op,
                    });
                }
                Some(Tok::Word(_)) => {
                    // Label or instruction: label iff followed by `:`
                    if matches!(self.toks.get(self.pos + 1).map(|s| &s.tok), Some(Tok::Colon)) {
                        let label = self.word()?;
                        self.pos += 1; // colon
                        body.push(Statement::Label(label));
                    } else {
                        let op = self.instruction()?;
                        body.push(Statement::Instr { guard: None, op });
                    }
                }
                Some(other) => {
                    return Err(self.err(format!("unexpected token `{other}` in kernel body")))
                }
            }
        }

        Ok(Kernel {
            name,
            params,
            regs,
            shared,
            body,
        })
    }

    fn operand(&mut self) -> Result<Operand, ParseError> {
        match self.peek().cloned() {
            Some(Tok::Word(w)) => {
                self.pos += 1;
                if let Some(sp) = Special::from_name(&w) {
                    Ok(Operand::Special(sp))
                } else if w.starts_with('%') {
                    Ok(Operand::Reg(Reg(w)))
                } else {
                    Ok(Operand::Var(w))
                }
            }
            Some(Tok::Int(_)) | Some(Tok::Minus) => Ok(Operand::ImmInt(self.int()?)),
            Some(Tok::F32Bits(b)) => {
                self.pos += 1;
                Ok(Operand::ImmF32(b))
            }
            Some(Tok::F64Bits(b)) => {
                self.pos += 1;
                Ok(Operand::ImmF64(b))
            }
            other => Err(self.err(format!("expected operand, got `{other:?}`"))),
        }
    }

    fn reg_operand(&mut self) -> Result<Reg, ParseError> {
        match self.operand()? {
            Operand::Reg(r) => Ok(r),
            other => Err(self.err(format!("expected register, got `{other:?}`"))),
        }
    }

    fn address(&mut self) -> Result<Address, ParseError> {
        self.expect(&Tok::LBracket)?;
        let base = self.operand()?;
        let mut offset = 0i64;
        if self.eat(&Tok::Plus) {
            offset = self.int()? as i64;
        } else if self.peek() == Some(&Tok::Minus) {
            offset = self.int()? as i64;
        }
        self.expect(&Tok::RBracket)?;
        Ok(Address { base, offset })
    }

    fn instruction(&mut self) -> Result<Op, ParseError> {
        let opcode = self.word()?;
        let parts: Vec<&str> = opcode.split('.').collect();
        let mnemonic = parts[0];
        let mods: Vec<&str> = parts[1..].to_vec();
        let op = self.dispatch(mnemonic, &mods, &opcode)?;
        self.expect(&Tok::Semi)?;
        Ok(op)
    }

    fn last_type(&self, mods: &[&str], opcode: &str) -> Result<Type, ParseError> {
        mods.iter()
            .rev()
            .find_map(|m| Type::from_suffix(m))
            .ok_or_else(|| self.err(format!("no type suffix in `{opcode}`")))
    }

    fn space_of(&self, mods: &[&str]) -> Option<Space> {
        mods.iter().find_map(|m| match *m {
            "param" => Some(Space::Param),
            "global" => Some(Space::Global),
            "shared" => Some(Space::Shared),
            "local" => Some(Space::Local),
            "const" => Some(Space::Const),
            _ => None,
        })
    }

    fn dispatch(&mut self, mnemonic: &str, mods: &[&str], opcode: &str) -> Result<Op, ParseError> {
        match mnemonic {
            "ld" => {
                let space = self.space_of(mods).unwrap_or(Space::Global);
                let nc = mods.contains(&"nc");
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let addr = self.address()?;
                Ok(Op::Ld {
                    space,
                    nc,
                    ty,
                    dst,
                    addr,
                })
            }
            "st" => {
                let space = self.space_of(mods).unwrap_or(Space::Global);
                let ty = self.last_type(mods, opcode)?;
                let addr = self.address()?;
                self.expect(&Tok::Comma)?;
                let src = self.operand()?;
                Ok(Op::St {
                    space,
                    ty,
                    addr,
                    src,
                })
            }
            "mov" => {
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let src = self.operand()?;
                Ok(Op::Mov { ty, dst, src })
            }
            "cvta" => {
                let to_global = mods.contains(&"to") && mods.contains(&"global");
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let src = self.operand()?;
                Ok(Op::Cvta { to_global, dst, src })
            }
            "add" | "sub" | "mul" | "div" | "rem" | "min" | "max" | "and" | "or" | "xor"
            | "shl" | "shr" => {
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                self.expect(&Tok::Comma)?;
                let b = self.operand()?;
                if ty.is_float() {
                    let op = match mnemonic {
                        "add" => FltBinOp::Add,
                        "sub" => FltBinOp::Sub,
                        "mul" => FltBinOp::Mul,
                        "div" => FltBinOp::Div,
                        "min" => FltBinOp::Min,
                        "max" => FltBinOp::Max,
                        _ => {
                            return Err(
                                self.err(format!("op `{opcode}` invalid for float type"))
                            )
                        }
                    };
                    Ok(Op::FltBin { op, ty, dst, a, b })
                } else {
                    let op = match mnemonic {
                        "add" => IntBinOp::Add,
                        "sub" => IntBinOp::Sub,
                        "mul" => {
                            if mods.contains(&"wide") {
                                IntBinOp::MulWide
                            } else if mods.contains(&"hi") {
                                IntBinOp::MulHi
                            } else {
                                IntBinOp::MulLo
                            }
                        }
                        "div" => IntBinOp::Div,
                        "rem" => IntBinOp::Rem,
                        "min" => IntBinOp::Min,
                        "max" => IntBinOp::Max,
                        "and" => IntBinOp::And,
                        "or" => IntBinOp::Or,
                        "xor" => IntBinOp::Xor,
                        "shl" => IntBinOp::Shl,
                        "shr" => IntBinOp::Shr,
                        _ => unreachable!(),
                    };
                    Ok(Op::IntBin { op, ty, dst, a, b })
                }
            }
            "mad" => {
                let wide = mods.contains(&"wide");
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                self.expect(&Tok::Comma)?;
                let b = self.operand()?;
                self.expect(&Tok::Comma)?;
                let c = self.operand()?;
                Ok(Op::Mad {
                    wide,
                    ty,
                    dst,
                    a,
                    b,
                    c,
                })
            }
            "fma" => {
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                self.expect(&Tok::Comma)?;
                let b = self.operand()?;
                self.expect(&Tok::Comma)?;
                let c = self.operand()?;
                Ok(Op::Fma { ty, dst, a, b, c })
            }
            "not" => {
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                Ok(Op::Not { ty, dst, a })
            }
            "neg" | "abs" => {
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                if ty.is_float() {
                    let op = if mnemonic == "neg" { FltUnOp::Neg } else { FltUnOp::Abs };
                    Ok(Op::FltUn { op, ty, dst, a })
                } else if mnemonic == "neg" {
                    Ok(Op::Neg { ty, dst, a })
                } else {
                    // integer abs: model as max(a, -a) at emulation; keep as Neg-less op
                    Err(self.err("integer abs not supported"))
                }
            }
            "sqrt" | "rsqrt" | "rcp" | "sin" | "cos" | "ex2" | "lg2" => {
                let ty = self.last_type(mods, opcode)?;
                let op = match mnemonic {
                    "sqrt" => FltUnOp::Sqrt,
                    "rsqrt" => FltUnOp::Rsqrt,
                    "rcp" => FltUnOp::Rcp,
                    "sin" => FltUnOp::Sin,
                    "cos" => FltUnOp::Cos,
                    "ex2" => FltUnOp::Ex2,
                    "lg2" => FltUnOp::Lg2,
                    _ => unreachable!(),
                };
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                Ok(Op::FltUn { op, ty, dst, a })
            }
            "setp" => {
                let cmp = mods
                    .iter()
                    .find_map(|m| CmpOp::from_suffix(m))
                    .ok_or_else(|| self.err(format!("no cmp op in `{opcode}`")))?;
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                self.expect(&Tok::Comma)?;
                let b = self.operand()?;
                Ok(Op::Setp { cmp, ty, dst, a, b })
            }
            "selp" => {
                let ty = self.last_type(mods, opcode)?;
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let a = self.operand()?;
                self.expect(&Tok::Comma)?;
                let b = self.operand()?;
                self.expect(&Tok::Comma)?;
                let p = self.operand()?;
                Ok(Op::Selp { ty, dst, a, b, p })
            }
            "cvt" => {
                let types: Vec<Type> = mods.iter().filter_map(|m| Type::from_suffix(m)).collect();
                if types.len() != 2 {
                    return Err(self.err(format!("cvt needs two type suffixes: `{opcode}`")));
                }
                let (dty, sty) = (types[0], types[1]);
                let dst = self.reg_operand()?;
                self.expect(&Tok::Comma)?;
                let src = self.operand()?;
                Ok(Op::Cvt { dty, sty, dst, src })
            }
            "bra" => {
                let uni = mods.contains(&"uni");
                let target = self.word()?;
                Ok(Op::Bra { uni, target })
            }
            "shfl" => {
                let mode = if mods.contains(&"up") {
                    ShflMode::Up
                } else if mods.contains(&"down") {
                    ShflMode::Down
                } else if mods.contains(&"bfly") {
                    ShflMode::Bfly
                } else if mods.contains(&"idx") {
                    ShflMode::Idx
                } else {
                    return Err(self.err(format!("no shfl mode in `{opcode}`")));
                };
                let dst = self.reg_operand()?;
                let pred_out = if self.eat(&Tok::Pipe) {
                    Some(self.reg_operand()?)
                } else {
                    None
                };
                self.expect(&Tok::Comma)?;
                let src = self.operand()?;
                self.expect(&Tok::Comma)?;
                let b = self.operand()?;
                self.expect(&Tok::Comma)?;
                let c = self.operand()?;
                self.expect(&Tok::Comma)?;
                let mask = self.operand()?;
                Ok(Op::Shfl {
                    mode,
                    dst,
                    pred_out,
                    src,
                    b,
                    c,
                    mask,
                })
            }
            "activemask" => {
                let dst = self.reg_operand()?;
                Ok(Op::Activemask { dst })
            }
            "bar" | "barrier" => {
                // full form: `bar.sync id [, cnt]` — the optional second
                // operand is the participating-thread count
                let id = match self.peek() {
                    Some(Tok::Int(_)) => self.int()? as u32,
                    _ => 0,
                };
                let cnt = if self.eat(&Tok::Comma) {
                    match self.peek() {
                        Some(Tok::Int(_)) => {
                            let c = self.int()?;
                            if c <= 0 || c > 1024 || c % 32 != 0 {
                                return Err(self.err(format!(
                                    "bar.sync thread count {c} is not a positive \
                                     multiple of the warp size (32) up to 1024"
                                )));
                            }
                            Some(c as u32)
                        }
                        other => {
                            return Err(self.err(format!(
                                "bar.sync thread count must be an immediate \
                                 integer, got `{other:?}` (register counts are \
                                 unsupported)"
                            )))
                        }
                    }
                } else {
                    None
                };
                Ok(Op::BarSync { id, cnt })
            }
            "ret" => Ok(Op::Ret),
            "exit" => Ok(Op::Exit),
            other => Err(self.err(format!("unknown instruction `{other}` in `{opcode}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ADD_KERNEL: &str = r#"
.version 7.6
.target sm_70
.address_size 64
.visible .entry add(.param .u64 c, .param .u64 a,
 .param .u64 b, .param .u64 f){
.reg .pred %p<2>;
.reg .f32 %f<4>;.reg .b32 %r<6>;.reg .b64 %rd<15>;
ld.param.u64 %rd1, [c];
ld.param.u64 %rd2, [a];
ld.param.u64 %rd3, [b];
ld.param.u64 %rd4, [f];
cvta.to.global.u64 %rd5, %rd4;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x; mad.lo.s32 %r1, %r3, %r2,%r4;
mul.wide.s32 %rd6, %r1, 4; add.s64 %rd7,%rd5,%rd6;
// if (!f[i]) goto $LABEL_EXIT;
ld.global.u32 %r5, [%rd7]; setp.eq.s32 %p1,%r5,0;
@%p1 bra $LABEL_EXIT;
cvta.u64 %rd8, %rd2; add.s64 %rd10, %rd8, %rd6;
cvta.u64 %rd11,%rd3; add.s64 %rd12, %rd11,%rd6;
ld.global.f32 %f1, [%rd12];
ld.global.f32 %f2, [%rd10]; add.f32 %f3, %f2, %f1;
cvta.u64 %rd13,%rd1; add.s64 %rd14, %rd13,%rd6;
st.global.f32 [%rd14], %f3;
$LABEL_EXIT: ret;
}
"#;

    #[test]
    fn parses_paper_listing2() {
        let m = parse(ADD_KERNEL).unwrap();
        assert_eq!(m.version, (7, 6));
        assert_eq!(m.target, "sm_70");
        assert_eq!(m.address_size, 64);
        assert_eq!(m.kernels.len(), 1);
        let k = &m.kernels[0];
        assert_eq!(k.name, "add");
        assert_eq!(k.params.len(), 4);
        assert_eq!(k.params[0].name, "c");
        assert_eq!(k.declared_regs(), 2 + 4 + 6 + 15);
        assert_eq!(k.global_loads(), 3);
        // label present
        assert!(k
            .body
            .iter()
            .any(|s| matches!(s, Statement::Label(l) if l == "$LABEL_EXIT")));
        // guarded branch present
        assert!(k.body.iter().any(|s| matches!(
            s,
            Statement::Instr {
                guard: Some(Guard { negated: false, .. }),
                op: Op::Bra { .. }
            }
        )));
    }

    #[test]
    fn parses_shfl_with_pred_out() {
        let src = r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>;
activemask.b32 %r1;
shfl.sync.up.b32 %r2|%p1, %r3, 2, 0, %r1;
@%p1 ld.global.nc.f32 %r2, [%rd1+4];
ret;
}
"#;
        let k = parse_kernel(src).unwrap();
        let shfl = k
            .body
            .iter()
            .find_map(|s| match s {
                Statement::Instr {
                    op: Op::Shfl { mode, pred_out, b, .. },
                    ..
                } => Some((mode, pred_out.clone(), b.clone())),
                _ => None,
            })
            .unwrap();
        assert_eq!(*shfl.0, ShflMode::Up);
        assert_eq!(shfl.1, Some(Reg::new("%p1")));
        assert_eq!(shfl.2, Operand::ImmInt(2));
    }

    #[test]
    fn parses_float_imm_and_negative_offsets() {
        let src = r#"
.visible .entry k(.param .u64 a){
.reg .f32 %f<3>; .reg .b64 %rd<3>;
mov.f32 %f1, 0f3F800000;
ld.global.f32 %f2, [%rd1+-8];
fma.rn.f32 %f1, %f1, %f2, 0f40000000;
ret;
}
"#;
        let k = parse_kernel(src).unwrap();
        assert!(k.body.iter().any(|s| matches!(
            s,
            Statement::Instr {
                op: Op::Mov {
                    src: Operand::ImmF32(0x3F80_0000),
                    ..
                },
                ..
            }
        )));
        assert!(k.body.iter().any(|s| matches!(
            s,
            Statement::Instr {
                op: Op::Ld { addr: Address { offset: -8, .. }, .. },
                ..
            }
        )));
    }

    #[test]
    fn parses_shared_decl() {
        let src = r#"
.visible .entry k(.param .u64 a){
.shared .align 4 .b8 smem[4096];
.reg .f32 %f<2>;
st.shared.f32 [smem+16], %f1;
ld.shared.f32 %f1, [smem+20];
bar.sync 0;
ret;
}
"#;
        let k = parse_kernel(src).unwrap();
        assert_eq!(k.shared.len(), 1);
        assert_eq!(k.shared[0].bytes, 4096);
        assert_eq!(k.shared[0].align, 4);
    }

    #[test]
    fn unknown_instruction_is_error() {
        let src = ".visible .entry k(){ frobnicate.u32 %r1, %r2; ret; }";
        assert!(parse(src).is_err());
    }

    #[test]
    fn selp_and_cvt() {
        let src = r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .f32 %f<3>; .reg .pred %p<2>;
setp.lt.s32 %p1, %r1, 32;
selp.b32 %r2, %r1, 0, %p1;
cvt.rn.f32.s32 %f1, %r2;
cvt.u64.u32 %rd1, %r2;
ret;
}
"#;
        let k = parse_kernel(src).unwrap();
        let cvts: Vec<_> = k
            .body
            .iter()
            .filter_map(|s| match s {
                Statement::Instr {
                    op: Op::Cvt { dty, sty, .. },
                    ..
                } => Some((*dty, *sty)),
                _ => None,
            })
            .collect();
        assert_eq!(cvts, vec![(Type::F32, Type::S32), (Type::U64, Type::U32)]);
    }

    #[test]
    fn bar_sync_full_form() {
        let src = r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<2>;
bar.sync 0;
bar.sync 1, 64;
bar.sync 2, 1024;
ret;
}
"#;
        let k = parse_kernel(src).unwrap();
        let bars: Vec<_> = k
            .body
            .iter()
            .filter_map(|s| match s {
                Statement::Instr {
                    op: Op::BarSync { id, cnt },
                    ..
                } => Some((*id, *cnt)),
                _ => None,
            })
            .collect();
        assert_eq!(bars, vec![(0, None), (1, Some(64)), (2, Some(1024))]);
    }

    #[test]
    fn bar_sync_bad_counts_are_clear_parse_errors() {
        const HDR: &str = ".visible .entry k(){ .reg .b32 %r<2>; ";
        for (cnt, why) in [("48", "multiple"), ("0", "multiple"), ("2048", "multiple")] {
            let src = format!("{HDR}bar.sync 0, {cnt}; ret; }}");
            let err = parse(&src).unwrap_err();
            assert!(
                err.msg.contains("thread count") && err.msg.contains(why),
                "cnt {cnt}: unexpected message `{}`",
                err.msg
            );
        }
        // a register count is rejected with its own message, not a stray
        // token error further down the line
        let err = parse(&format!("{HDR}bar.sync 0, %r1; ret; }}")).unwrap_err();
        assert!(
            err.msg.contains("immediate"),
            "unexpected message `{}`",
            err.msg
        );
    }
}
