//! PTX substrate: lexer, AST, parser, printer.
//!
//! PTX is the paper's interchange layer: user-level compilers (NVHPC, nvcc)
//! emit it, PTXASW rewrites it, and the vendor assembler consumes it. Here
//! the `suite` module plays the role of NVHPC, and `sim` plays the GPU.

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod printer;

pub use ast::*;
pub use parser::{parse, parse_kernel, ParseError};
pub use printer::{kernel_fingerprint, print_kernel, print_module, print_op, ContentHash};
