//! Tokenizer for PTX assembly text.
//!
//! PTX "words" may contain dots (`ld.global.nc.f32`, `%tid.x`, `.visible`),
//! dollar signs (labels like `$L__BB0_2`) and percent signs (registers).
//! The lexer groups those into single `Word` tokens and leaves splitting on
//! dots to the parser.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier-ish word: opcode, register, directive, label name.
    Word(String),
    /// Integer literal (decimal or 0x hex), sign handled by parser.
    Int(i128),
    /// `0f3F800000` → raw f32 bits.
    F32Bits(u32),
    /// `0d3FF0000000000000` → raw f64 bits.
    F64Bits(u64),
    LBrace,
    RBrace,
    LParen,
    RParen,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Colon,
    Pipe,
    Plus,
    Minus,
    At,
    Bang,
    Lt,
    Gt,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Word(w) => write!(f, "{w}"),
            Tok::Int(v) => write!(f, "{v}"),
            Tok::F32Bits(b) => write!(f, "0f{b:08X}"),
            Tok::F64Bits(b) => write!(f, "0d{b:016X}"),
            Tok::LBrace => write!(f, "{{"),
            Tok::RBrace => write!(f, "}}"),
            Tok::LParen => write!(f, "("),
            Tok::RParen => write!(f, ")"),
            Tok::LBracket => write!(f, "["),
            Tok::RBracket => write!(f, "]"),
            Tok::Comma => write!(f, ","),
            Tok::Semi => write!(f, ";"),
            Tok::Colon => write!(f, ":"),
            Tok::Pipe => write!(f, "|"),
            Tok::Plus => write!(f, "+"),
            Tok::Minus => write!(f, "-"),
            Tok::At => write!(f, "@"),
            Tok::Bang => write!(f, "!"),
            Tok::Lt => write!(f, "<"),
            Tok::Gt => write!(f, ">"),
        }
    }
}

/// A token plus its 1-based source line (for diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub struct Spanned {
    pub tok: Tok,
    pub line: u32,
}

#[derive(Debug)]
pub struct LexError {
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for LexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lex error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for LexError {}

fn is_word_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == '%' || c == '$' || c == '.'
}

fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '%' || c == '$' || c == '.'
}

/// Tokenize a full PTX source string.
pub fn lex(src: &str) -> Result<Vec<Spanned>, LexError> {
    let mut out = Vec::with_capacity(src.len() / 4);
    let mut chars = src.char_indices().peekable();
    let bytes = src.as_bytes();
    let mut line: u32 = 1;

    while let Some((i, c)) = chars.next() {
        match c {
            '\n' => line += 1,
            c if c.is_whitespace() => {}
            '/' => match chars.peek() {
                Some((_, '/')) => {
                    for (_, c2) in chars.by_ref() {
                        if c2 == '\n' {
                            line += 1;
                            break;
                        }
                    }
                }
                Some((_, '*')) => {
                    chars.next();
                    let mut prev = ' ';
                    let mut closed = false;
                    for (_, c2) in chars.by_ref() {
                        if c2 == '\n' {
                            line += 1;
                        }
                        if prev == '*' && c2 == '/' {
                            closed = true;
                            break;
                        }
                        prev = c2;
                    }
                    if !closed {
                        return Err(LexError {
                            line,
                            msg: "unterminated block comment".into(),
                        });
                    }
                }
                _ => {
                    return Err(LexError {
                        line,
                        msg: "stray '/'".into(),
                    })
                }
            },
            '{' => out.push(Spanned { tok: Tok::LBrace, line }),
            '}' => out.push(Spanned { tok: Tok::RBrace, line }),
            '(' => out.push(Spanned { tok: Tok::LParen, line }),
            ')' => out.push(Spanned { tok: Tok::RParen, line }),
            '[' => out.push(Spanned { tok: Tok::LBracket, line }),
            ']' => out.push(Spanned { tok: Tok::RBracket, line }),
            ',' => out.push(Spanned { tok: Tok::Comma, line }),
            ';' => out.push(Spanned { tok: Tok::Semi, line }),
            ':' => out.push(Spanned { tok: Tok::Colon, line }),
            '|' => out.push(Spanned { tok: Tok::Pipe, line }),
            '+' => out.push(Spanned { tok: Tok::Plus, line }),
            '-' => out.push(Spanned { tok: Tok::Minus, line }),
            '@' => out.push(Spanned { tok: Tok::At, line }),
            '!' => out.push(Spanned { tok: Tok::Bang, line }),
            '<' => out.push(Spanned { tok: Tok::Lt, line }),
            '>' => out.push(Spanned { tok: Tok::Gt, line }),
            c if c.is_ascii_digit() => {
                let start = i;
                let mut end = i + 1;
                while let Some(&(j, c2)) = chars.peek() {
                    if c2.is_ascii_alphanumeric() {
                        end = j + c2.len_utf8();
                        chars.next();
                    } else {
                        break;
                    }
                }
                let text = &src[start..end];
                out.push(Spanned {
                    tok: lex_number(text, line)?,
                    line,
                });
                let _ = bytes;
            }
            c if is_word_start(c) => {
                let start = i;
                let mut end = i + c.len_utf8();
                while let Some(&(j, c2)) = chars.peek() {
                    if is_word_char(c2) {
                        end = j + c2.len_utf8();
                        chars.next();
                    } else {
                        break;
                    }
                }
                out.push(Spanned {
                    tok: Tok::Word(src[start..end].to_string()),
                    line,
                });
            }
            other => {
                return Err(LexError {
                    line,
                    msg: format!("unexpected character {other:?}"),
                })
            }
        }
    }
    Ok(out)
}

fn lex_number(text: &str, line: u32) -> Result<Tok, LexError> {
    let err = |msg: String| LexError { line, msg };
    if let Some(hex) = text.strip_prefix("0f").or_else(|| text.strip_prefix("0F")) {
        if hex.len() == 8 {
            return u32::from_str_radix(hex, 16)
                .map(Tok::F32Bits)
                .map_err(|e| err(format!("bad f32 literal {text}: {e}")));
        }
    }
    if let Some(hex) = text.strip_prefix("0d").or_else(|| text.strip_prefix("0D")) {
        if hex.len() == 16 {
            return u64::from_str_radix(hex, 16)
                .map(Tok::F64Bits)
                .map_err(|e| err(format!("bad f64 literal {text}: {e}")));
        }
    }
    if let Some(hex) = text.strip_prefix("0x").or_else(|| text.strip_prefix("0X")) {
        return i128::from_str_radix(hex, 16)
            .map(Tok::Int)
            .map_err(|e| err(format!("bad hex literal {text}: {e}")));
    }
    // PTX allows a trailing 'U' on decimal literals.
    let dec = text.strip_suffix('U').unwrap_or(text);
    dec.parse::<i128>()
        .map(Tok::Int)
        .map_err(|e| err(format!("bad integer literal {text}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|s| s.tok).collect()
    }

    #[test]
    fn words_keep_dots() {
        assert_eq!(
            toks("ld.global.nc.f32 %f1, [%rd7+4];"),
            vec![
                Tok::Word("ld.global.nc.f32".into()),
                Tok::Word("%f1".into()),
                Tok::Comma,
                Tok::LBracket,
                Tok::Word("%rd7".into()),
                Tok::Plus,
                Tok::Int(4),
                Tok::RBracket,
                Tok::Semi,
            ]
        );
    }

    #[test]
    fn comments_skipped() {
        assert_eq!(
            toks("add.s32 %r1, %r2, %r3; // c = a + b\n/* block\ncomment */ ret;"),
            vec![
                Tok::Word("add.s32".into()),
                Tok::Word("%r1".into()),
                Tok::Comma,
                Tok::Word("%r2".into()),
                Tok::Comma,
                Tok::Word("%r3".into()),
                Tok::Semi,
                Tok::Word("ret".into()),
                Tok::Semi,
            ]
        );
    }

    #[test]
    fn float_literals() {
        assert_eq!(toks("0f3F800000"), vec![Tok::F32Bits(0x3F80_0000)]);
        assert_eq!(
            toks("0d3FF0000000000000"),
            vec![Tok::F64Bits(0x3FF0_0000_0000_0000)]
        );
    }

    #[test]
    fn hex_and_negative() {
        assert_eq!(toks("0xFF"), vec![Tok::Int(255)]);
        assert_eq!(toks("-1"), vec![Tok::Minus, Tok::Int(1)]);
    }

    #[test]
    fn guard_tokens() {
        assert_eq!(
            toks("@!%p1 bra $L_END;"),
            vec![
                Tok::At,
                Tok::Bang,
                Tok::Word("%p1".into()),
                Tok::Word("bra".into()),
                Tok::Word("$L_END".into()),
                Tok::Semi,
            ]
        );
    }

    #[test]
    fn reg_decl_tokens() {
        assert_eq!(
            toks(".reg .f32 %f<4>;"),
            vec![
                Tok::Word(".reg".into()),
                Tok::Word(".f32".into()),
                Tok::Word("%f".into()),
                Tok::Lt,
                Tok::Int(4),
                Tok::Gt,
                Tok::Semi,
            ]
        );
    }

    #[test]
    fn line_tracking() {
        let s = lex("add\nsub\nmul").unwrap();
        assert_eq!(s[0].line, 1);
        assert_eq!(s[1].line, 2);
        assert_eq!(s[2].line, 3);
    }

    #[test]
    fn unterminated_comment_errors() {
        assert!(lex("/* nope").is_err());
    }
}
