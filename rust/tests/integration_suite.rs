//! Suite-level integration: the full pipeline (generate → emulate → detect
//! → synthesize → simulate) over all 16 KernelGen benchmarks and the §8.5
//! app kernels.
//!
//! Checks three levels:
//!  1. Table 2 reproduction: shuffle/load counts and average deltas.
//!  2. Simulation correctness: generated PTX matches the CPU reference
//!     bit-exactly.
//!  3. Semantics preservation: the PTXASW and UNIFORM variants stay
//!     bit-exact after synthesis; NO LOAD / NO CORNER still run.

use ptxasw::emu::emulate;
use ptxasw::shuffle::{detect, synthesize, DetectOpts, Variant};
use ptxasw::sim::run;
use ptxasw::suite::{apps, shared_suite, suite, workload, Pattern};

fn sizes_for(b: &ptxasw::suite::Benchmark) -> (usize, usize, usize) {
    match &b.pattern {
        Pattern::MatMul { .. } => (48, 6, 8),
        Pattern::MatVec { .. } => (96, 1, 3),
        _ if b.dims == 3 => (40, 10, 8),
        _ => (96, 8, 1),
    }
}

#[test]
fn table2_shuffles_loads_deltas() {
    let mut rows = Vec::new();
    for b in suite() {
        let k = ptxasw::suite::generate(&b);
        let res = emulate(&k).unwrap_or_else(|e| panic!("{}: emulation failed: {e}", b.name));
        let det = detect(&k, &res, DetectOpts::default());
        rows.push((
            b.name,
            det.shuffle_count(),
            det.total_global_loads,
            det.avg_delta(),
        ));
        assert_eq!(
            det.shuffle_count(),
            b.expect_shuffles,
            "{}: shuffles (got {:?})",
            b.name,
            det.chosen
        );
        assert_eq!(det.total_global_loads, b.expect_loads, "{}: loads", b.name);
        match (det.avg_delta(), b.expect_delta) {
            (None, None) => {}
            (Some(got), Some(want)) => {
                assert!(
                    (got - want).abs() < 1e-6,
                    "{}: delta {} != {}",
                    b.name,
                    got,
                    want
                );
            }
            (got, want) => panic!("{}: delta {got:?} vs {want:?}", b.name),
        }
    }
    // sanity print for the harness
    for (n, s, l, d) in rows {
        eprintln!("{n:12} {s:3}/{l:3} delta={d:?}");
    }
}

#[test]
fn generated_kernels_match_cpu_reference() {
    for b in suite() {
        let (nx, ny, nz) = sizes_for(&b);
        let w = workload(&b, nx, ny, nz, 42);
        let r = run(&w.kernel, &w.cfg, w.mem).unwrap_or_else(|e| panic!("{}: {e}", b.name));
        let got = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
        let diff = got
            .iter()
            .zip(&w.expected)
            .enumerate()
            .find(|(_, (a, b))| a.to_bits() != b.to_bits());
        assert!(
            diff.is_none(),
            "{}: first mismatch at {:?}",
            b.name,
            diff.map(|(i, (a, e))| (i, *a, *e))
        );
    }
}

#[test]
fn synthesized_variants_preserve_semantics() {
    for b in suite() {
        if b.expect_shuffles == 0 {
            continue;
        }
        let (nx, ny, nz) = sizes_for(&b);
        let k = ptxasw::suite::generate(&b);
        let res = emulate(&k).unwrap();
        let det = detect(&k, &res, DetectOpts::default());
        for v in [Variant::Full, Variant::UniformBranch] {
            let sk = synthesize(&k, &det, v);
            let w = workload(&b, nx, ny, nz, 777);
            let r =
                run(&sk, &w.cfg, w.mem).unwrap_or_else(|e| panic!("{} {}: {e}", b.name, v.name()));
            let got = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
            let bad = got
                .iter()
                .zip(&w.expected)
                .filter(|(a, b)| a.to_bits() != b.to_bits())
                .count();
            assert_eq!(bad, 0, "{} {}: {bad} mismatches", b.name, v.name());
        }
        // perf variants run without faulting
        for v in [Variant::NoLoad, Variant::NoCorner] {
            let sk = synthesize(&k, &det, v);
            let w = workload(&b, nx, ny, nz, 777);
            run(&sk, &w.cfg, w.mem).unwrap_or_else(|e| panic!("{} {}: {e}", b.name, v.name()));
        }
    }
}

/// The shared-memory family (tiled reduction, shared-staged stencil)
/// flows through the complete pipeline — generate → emulate (barrier
/// phases segmenting the trace) → detect → synthesize → validate → score
/// — with bit-exact simulator output and no cross-phase shuffles.
#[test]
fn shared_suite_full_pipeline() {
    use ptxasw::coordinator::{run_benchmark, PipelineConfig};
    for b in shared_suite() {
        // static expectations: load counts, and barriers make shuffles
        // impossible under the default options
        let k = ptxasw::suite::generate(&b);
        let res = emulate(&k).unwrap_or_else(|e| panic!("{}: emulation failed: {e}", b.name));
        assert!(
            res.stats.barriers > 0,
            "{}: the emulator must walk the barriers",
            b.name
        );
        let det = detect(&k, &res, DetectOpts::default());
        assert_eq!(det.total_global_loads, b.expect_loads, "{}: loads", b.name);
        assert_eq!(det.shuffle_count(), b.expect_shuffles, "{}: shuffles", b.name);

        // end-to-end: emulate → detect → synthesize → validate → score
        let r = run_benchmark(&b, &PipelineConfig::default())
            .unwrap_or_else(|e| panic!("{}: pipeline failed: {e}", b.name));
        assert!(
            r.baseline.sim_stats.barriers > 0,
            "{}: simulated barriers",
            b.name
        );
        assert!(r.baseline.sim_stats.barrier_phases > 0, "{}", b.name);
        for (v, o) in &r.variants {
            assert_eq!(
                o.valid,
                Some(true),
                "{} {}: synthesized variant must stay bit-exact",
                b.name,
                v.name()
            );
            assert!(!o.reports.is_empty(), "{}: scored", b.name);
        }
    }
}

/// Loads on opposite sides of a `bar.sync` must never be paired, even
/// when they are same-segment, same-array and constant-delta — the
/// values are exchanged through memory at the barrier.
#[test]
fn detection_never_pairs_loads_across_a_barrier() {
    let k = ptxasw::ptx::parser::parse_kernel(
        r#"
.visible .entry xb(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
bar.sync 0;
ld.global.nc.f32 %f2, [%rd6+4];
add.f32 %f3, %f1, %f2;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f3;
ret;
}
"#,
    )
    .unwrap();
    let res = emulate(&k).unwrap();
    let det = detect(&k, &res, DetectOpts::default());
    assert_eq!(
        det.shuffle_count(),
        0,
        "a bar.sync between the loads must veto the pair: {:?}",
        det.chosen
    );
    // the identical kernel without the barrier detects the N=1 shuffle
    let k2 = ptxasw::ptx::parser::parse_kernel(
        &ptxasw::ptx::printer::print_kernel(&k).replace("bar.sync 0;\n", ""),
    )
    .unwrap();
    let res2 = emulate(&k2).unwrap();
    let det2 = detect(&k2, &res2, DetectOpts::default());
    assert_eq!(det2.shuffle_count(), 1);
    assert_eq!(det2.chosen[0].delta, 1);
}

#[test]
fn app_kernels_match_section85() {
    for b in apps() {
        let k = ptxasw::suite::generate(&b);
        let res = emulate(&k).unwrap();
        // §8.5 restricts synthesis to |N| ≤ 1
        let det = detect(&k, &res, DetectOpts { max_abs_delta: 1, ..Default::default() });
        assert_eq!(det.total_global_loads, b.expect_loads, "{}: loads", b.name);
        assert_eq!(
            det.shuffle_count(),
            b.expect_shuffles,
            "{}: shuffles",
            b.name
        );
        if let Some(d) = b.expect_delta {
            let got = det.avg_delta().unwrap();
            assert!((got - d).abs() < 1e-6, "{}: |N| = {got}", b.name);
        }
    }
}

#[test]
fn app_kernels_simulate_and_preserve() {
    // the big rhs4th3fort kernel end-to-end with |N| ≤ 1 synthesis
    let b = ptxasw::suite::apps::rhs4th3fort();
    let k = ptxasw::suite::generate(&b);
    let res = emulate(&k).unwrap();
    let det = detect(&k, &res, DetectOpts { max_abs_delta: 1, ..Default::default() });
    let sk = synthesize(&k, &det, Variant::Full);

    let w = workload(&b, 40, 12, 12, 9);
    let r = run(&k, &w.cfg, w.mem).unwrap();
    let orig = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
    let exp_bad = orig
        .iter()
        .zip(&w.expected)
        .filter(|(a, b)| a.to_bits() != b.to_bits())
        .count();
    assert_eq!(exp_bad, 0, "original vs CPU reference");

    let w2 = workload(&b, 40, 12, 12, 9);
    let r2 = run(&sk, &w2.cfg, w2.mem).unwrap();
    let synth = r2.mem.read_f32s(w2.out_ptr, w2.out_len).unwrap();
    assert_eq!(orig, synth, "synthesized vs original");
}
