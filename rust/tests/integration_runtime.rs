//! Runtime integration: PJRT-executed Pallas/JAX artifacts vs the warp
//! simulator running the generated PTX of the same stencils — the
//! three-layer composition proof. Requires `make artifacts`.

use ptxasw::runtime::Runtime;
use ptxasw::sim::run;
use ptxasw::suite::{by_name, workload};
use ptxasw::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.txt").exists().then_some(d)
}

#[test]
fn pjrt_executes_jacobi_artifact() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = Runtime::open(dir).unwrap();
    assert!(rt.names().contains(&"jacobi"));
    let spec = rt.spec("jacobi").unwrap().clone();
    let n = spec.args[0].elements();
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() * 4.0 - 2.0).collect();
    let out = rt.run_f32("jacobi", &[&x]).unwrap();
    assert_eq!(out.len(), n);
    // halo ring is zero; interior is not
    let (ny, nx) = (spec.args[0].dims[0], spec.args[0].dims[1]);
    for i in 0..nx {
        assert_eq!(out[i], 0.0);
        assert_eq!(out[(ny - 1) * nx + i], 0.0);
    }
    assert!(out.iter().any(|&v| v != 0.0));
}

#[test]
fn pjrt_matches_simulated_ptx_jacobi() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = Runtime::open(dir).unwrap();
    let dims = rt.spec("jacobi").unwrap().args[0].dims.clone();
    let (ny, nx) = (dims[0], dims[1]);

    // same input through both worlds
    let b = by_name("jacobi").unwrap();
    let w = workload(&b, nx, ny, 1, 123);
    let input = w
        .mem
        .read_f32s(w.cfg.params[1], nx * ny)
        .unwrap();

    let pjrt_out = rt.run_f32("jacobi", &[&input]).unwrap();
    let r = run(&w.kernel, &w.cfg, w.mem).unwrap();
    let sim_out = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();

    let mut max_err = 0f32;
    for (a, b) in pjrt_out.iter().zip(&sim_out) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 1e-5,
        "PJRT vs simulator mismatch: max abs err {max_err}"
    );
}

#[test]
fn tiled_and_plain_artifacts_agree() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = Runtime::open(dir).unwrap();
    let n = rt.spec("jacobi").unwrap().args[0].elements();
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let plain = rt.run_f32("jacobi", &[&x]).unwrap();
    let tiled = rt.run_f32("jacobi_tiled", &[&x]).unwrap();
    for (a, b) in plain.iter().zip(&tiled) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn scan_artifact_equals_four_applications() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rt = Runtime::open(dir).unwrap();
    let n = rt.spec("jacobi").unwrap().args[0].elements();
    let mut rng = Rng::new(6);
    let x: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let mut iterated = x.clone();
    for _ in 0..4 {
        iterated = rt.run_f32("jacobi", &[&iterated]).unwrap();
    }
    let scanned = rt.run_f32("jacobi_x4", &[&x]).unwrap();
    for (a, b) in scanned.iter().zip(&iterated) {
        assert!((a - b).abs() < 1e-5);
    }
}
