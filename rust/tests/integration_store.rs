//! Cross-process cache persistence: a pipeline opened over a warmed cache
//! directory must serve detection, synthesis, simulation and scoring from
//! disk (zero emulations, zero simulations); corrupt or truncated store
//! files must recompute instead of panicking; the store must stay within
//! its size bound via LRU eviction.

use ptxasw::coordinator::{report, run_suite_on, BenchResult, PipelineConfig, PipelineError};
use ptxasw::pipeline::{DiskStore, Pipeline, Stage, DEFAULT_MAX_BYTES};
use ptxasw::ptx::parser::parse_kernel;
use ptxasw::shuffle::DetectOpts;
use ptxasw::sim::SimError;
use ptxasw::suite::{by_name, Benchmark};
use std::path::{Path, PathBuf};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ptxasw-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn benches() -> Vec<Benchmark> {
    // vecadd/gradient for the classic class; tiledreduce so the
    // shared-memory/barrier path (cooperative scheduler, phase-segmented
    // emulation) is exercised through the full pipeline + disk store too
    ["vecadd", "gradient", "tiledreduce"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect()
}

/// All `.art` files under a cache directory, recursively.
fn art_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(rd) = std::fs::read_dir(&d) else { continue };
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().and_then(|x| x.to_str()) == Some("art") {
                out.push(p);
            }
        }
    }
    out
}

fn unwrap_all(results: Vec<Result<BenchResult, PipelineError>>) -> Vec<BenchResult> {
    results
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("benchmark failed: {e}")))
        .collect()
}

fn assert_same_results(a: &[BenchResult], b: &[BenchResult]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.detection.chosen, y.detection.chosen);
        assert_eq!(x.detection.total_global_loads, y.detection.total_global_loads);
        assert_eq!(x.baseline.valid, y.baseline.valid);
        for ((xv, xo), (yv, yo)) in x.variants.iter().zip(&y.variants) {
            assert_eq!(xv, yv);
            assert_eq!(xo.valid, yo.valid, "{}: validity diverged", x.name);
            for (xr, yr) in xo.reports.iter().zip(&yo.reports) {
                assert_eq!(
                    xr.effective_cycles.to_bits(),
                    yr.effective_cycles.to_bits(),
                    "{}: modelled cycles diverged between runs",
                    x.name
                );
            }
        }
    }
}

/// Acceptance: a second identical suite run in the same process *and* in
/// a fresh process (same cache dir) performs zero emulations and zero
/// simulations.
#[test]
fn warm_runs_skip_emulation_and_simulation() {
    let dir = tmpdir("warm");
    let cfg = PipelineConfig::default();
    let bs = benches();

    let p1 = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let first = unwrap_all(run_suite_on(&p1, &bs, &cfg));
    let s1 = p1.stats();
    assert!(s1.disk.stores > 0, "cold run must persist artifacts");
    assert!(s1.cache.validate_misses > 0);

    // same process, same pipeline: everything is a memory hit
    let again = unwrap_all(run_suite_on(&p1, &bs, &cfg));
    let s1b = p1.stats();
    assert_eq!(s1b.cache.emulate_misses, s1.cache.emulate_misses);
    assert_eq!(s1b.cache.validate_misses, s1.cache.validate_misses);
    assert_eq!(s1b.stage_count(Stage::Validate), s1.stage_count(Stage::Validate));
    assert_same_results(&first, &again);

    // fresh pipeline + fresh store over the same directory — the
    // stand-in for a fresh process
    let p2 = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let second = unwrap_all(run_suite_on(&p2, &bs, &cfg));
    let s2 = p2.stats();
    assert_eq!(s2.stage_count(Stage::Emulate), 0, "zero emulations on warm run");
    assert_eq!(s2.stage_count(Stage::Decode), 0, "zero decodes on warm run");
    assert_eq!(s2.stage_count(Stage::Validate), 0, "zero simulations on warm run");
    assert_eq!(s2.stage_count(Stage::Score), 0, "zero model runs on warm run");
    assert_eq!(s2.cache.emulate_misses, 0);
    assert_eq!(s2.cache.decode_misses, 0);
    assert_eq!(s2.cache.validate_misses, 0);
    assert_eq!(s2.cache.score_misses, 0);
    assert!(s2.cache.disk_hits() > 0, "artifacts must come from disk");
    assert!(s2.disk.hits > 0);
    assert_same_results(&first, &second);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance for the term-graph codec: a fresh process on a warmed cache
/// dir performs **zero symbolic emulations and zero decodes** even for
/// queries that force downstream recomputation — different detection
/// options re-detect from the *disk-loaded* emulation, a different
/// workload seed re-simulates from the *disk-loaded* decoded kernels —
/// and the results are identical to computing everything fresh (the
/// system-level eval-agreement differential).
#[test]
fn unseen_queries_reuse_emulated_and_decoded_artifacts() {
    let dir = tmpdir("reloc");
    let bs = benches();

    let p1 = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    unwrap_all(run_suite_on(&p1, &bs, &PipelineConfig::default()));
    assert!(p1.stats().disk.stores > 0, "cold run must persist artifacts");

    // fresh process, new detection options + new workload seed: every
    // kernel-keyed downstream stage misses, but emulation and decoding
    // must be served from the relocatable disk images
    let warm_cfg = PipelineConfig {
        seed: 43,
        detect: DetectOpts {
            max_abs_delta: 30,
            ..DetectOpts::default()
        },
        ..PipelineConfig::default()
    };
    let p2 = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let from_disk = unwrap_all(run_suite_on(&p2, &bs, &warm_cfg));
    let s2 = p2.stats();
    assert!(s2.cache.detect_misses > 0, "new opts must re-detect");
    assert!(s2.cache.validate_misses > 0, "new seed must re-simulate");
    assert_eq!(s2.stage_count(Stage::Emulate), 0, "zero symbolic emulations");
    assert_eq!(s2.stage_count(Stage::Decode), 0, "zero decodes");
    assert!(
        s2.cache.emulate_disk_hits >= bs.len() as u64,
        "every emulation must come from disk (got {})",
        s2.cache.emulate_disk_hits
    );
    assert!(
        s2.cache.decode_disk_hits > 0,
        "decoded kernels must come from disk"
    );

    // semantically identical to a cache-less computation of the same query
    let clean = unwrap_all(run_suite_on(&Pipeline::new(), &bs, &warm_cfg));
    assert_same_results(&clean, &from_disk);

    let _ = std::fs::remove_dir_all(&dir);
}

/// `--detect-races` runs must neither consume nor produce `validated/`
/// disk artifacts: a verdict simulated without the load-side shadow must
/// not satisfy a diagnostic query.
#[test]
fn detect_races_bypasses_the_validated_disk_cache() {
    // every block stores out[ctaid] then reads out[0] — a cross-block
    // read-after-write on any multi-block grid
    const RACY: &str = r#"
.visible .entry racy(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<6>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %ctaid.x;
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd4, %rd2, %rd3;
st.global.b32 [%rd4], %r1;
ld.global.b32 %r2, [%rd2];
ret;
}
"#;
    let dir = tmpdir("races");
    let b = by_name("vecadd").unwrap();
    let sizes = (96, 8, 1);
    let racy = parse_kernel(RACY).unwrap();

    // a normal pipeline validates the racy kernel fine and persists it
    let p1 = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let w1 = p1.workload_art(&b, sizes, 42);
    let parsed1 = p1.intake(racy.clone());
    p1.validated(&parsed1.kernel, parsed1.hash, &w1, None)
        .expect("diagnostic off: the racy kernel simulates fine");
    assert!(p1.stats().disk.stores > 0);

    // a diagnostic pipeline over the same dir must not serve the cached
    // verdict — the race is a hard error
    let p2 = Pipeline::new()
        .with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap())
        .with_detect_races(true);
    let w2 = p2.workload_art(&b, sizes, 42);
    let parsed2 = p2.intake(racy);
    let err = p2
        .validated(&parsed2.kernel, parsed2.hash, &w2, None)
        .expect_err("diagnostic on: the cached verdict must not mask the race");
    assert!(
        matches!(err, SimError::CrossBlockRace { .. }),
        "expected CrossBlockRace, got {err:?}"
    );
    // ...and the diagnostic run must not have written a validated
    // artifact either (its only store traffic could be decode/emulate
    // images, which were already present)
    assert_eq!(p2.stats().disk.stores, 0, "diagnostic runs never persist verdicts");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupted or truncated store files fall back to recompute — results
/// identical to a cache-less run, no panic, corruption counted.
#[test]
fn corrupt_and_truncated_artifacts_recompute() {
    let dir = tmpdir("corrupt");
    let cfg = PipelineConfig::default();
    let bs = benches();

    let p1 = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    unwrap_all(run_suite_on(&p1, &bs, &cfg));

    // mangle every artifact: truncate half, bit-flip the rest
    let files = art_files(&dir);
    assert!(!files.is_empty(), "cold run must have written artifacts");
    for (i, f) in files.iter().enumerate() {
        let bytes = std::fs::read(f).unwrap();
        if i % 2 == 0 {
            std::fs::write(f, &bytes[..bytes.len().min(5)]).unwrap();
        } else {
            let mut b = bytes;
            let mid = b.len() / 2;
            b[mid] ^= 0xFF;
            std::fs::write(f, &b).unwrap();
        }
    }

    let p2 = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let recomputed = unwrap_all(run_suite_on(&p2, &bs, &cfg));
    let s = p2.stats();
    assert!(s.disk.corrupt > 0, "mangled files must be detected");
    assert!(s.cache.validate_misses > 0, "must fall back to recompute");

    // identical to a run with no disk store at all
    let clean = unwrap_all(run_suite_on(&Pipeline::new(), &bs, &cfg));
    assert_same_results(&clean, &recomputed);

    let _ = std::fs::remove_dir_all(&dir);
}

/// The store evicts least-recently-used artifacts to stay within its
/// size bound.
#[test]
fn eviction_keeps_store_within_bound() {
    let dir = tmpdir("evict");
    let cfg = PipelineConfig::default();
    let bound = 64 * 1024;

    let p = Pipeline::new().with_disk(DiskStore::open(&dir, bound).unwrap());
    unwrap_all(run_suite_on(&p, &benches(), &cfg));
    let s = p.stats();
    assert!(s.disk.evictions > 0, "the suite's artifacts exceed the bound");

    let total: u64 = art_files(&dir)
        .iter()
        .map(|f| std::fs::metadata(f).map(|m| m.len()).unwrap_or(0))
        .sum();
    assert!(
        total <= bound,
        "resident artifacts ({total} bytes) exceed the bound ({bound})"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// Cross-process coordination (stood in by independent `DiskStore`
/// handles over one directory): two writers — racing each other on a
/// shared key range — and two evictors running concurrently must never
/// panic, never serve wrong bytes, and leave a coherent store a final
/// eviction pass brings under its bound.
#[test]
fn concurrent_writers_and_evictors_keep_the_store_coherent() {
    use ptxasw::pipeline::{KeyBuilder, StoreKind};
    let dir = tmpdir("mp");
    let bound: u64 = 48 * 1024;
    let payload = |id: u64| -> Vec<u8> {
        let mut rng = ptxasw::util::Rng::new(id | 1);
        (0..1024).map(|_| rng.below(256) as u8).collect()
    };
    let key = |id: u64| KeyBuilder::new("mp-test").u64(id).finish();
    // seed the dir so every later open scans a non-empty store
    DiskStore::open(&dir, bound)
        .unwrap()
        .store(StoreKind::Scored, key(0), &payload(0));

    std::thread::scope(|s| {
        // two writers: distinct ranges plus a shared racing range whose
        // payloads are identical by construction (any winner is right)
        for w in 0..2u64 {
            let dir = dir.clone();
            s.spawn(move || {
                let store = DiskStore::open(&dir, bound).unwrap();
                for i in 0..120u64 {
                    let id = if i % 3 == 0 { 5000 + i } else { w * 10_000 + i };
                    store.store(StoreKind::Scored, key(id), &payload(id));
                    // read-back of an id some other actor may be evicting
                    if let Some(bytes) = store.load(StoreKind::Scored, key(5000 + i - i % 3)) {
                        assert_eq!(bytes, payload(5000 + i - i % 3), "poisoned read");
                    }
                }
            });
        }
        // two evictors: fresh handles (their open-time scan seeds the
        // resident counter) aggressively evicting while writers run
        for _ in 0..2 {
            let dir = dir.clone();
            s.spawn(move || {
                for _ in 0..15 {
                    let store = DiskStore::open(&dir, bound).unwrap();
                    store.evict_to_limit();
                }
            });
        }
    });

    // the dust settles: one more handle, one more eviction pass
    let store = DiskStore::open(&dir, bound).unwrap();
    store.evict_to_limit();
    let total: u64 = art_files(&dir)
        .iter()
        .map(|f| std::fs::metadata(f).map(|m| m.len()).unwrap_or(0))
        .sum();
    assert!(
        total <= bound,
        "store incoherent after concurrent traffic: {total} resident bytes > {bound}"
    );
    let snap = store.snapshot();
    assert!(
        snap.generation >= 1,
        "evictions must have published manifest generations"
    );
    // every surviving artifact still round-trips exactly
    for id in (0..120u64).flat_map(|i| [5000 + i, i, 10_000 + i]) {
        if let Some(bytes) = store.load(StoreKind::Scored, key(id)) {
            assert_eq!(bytes, payload(id), "artifact {id} corrupted by the race");
        }
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// Eviction-scan hardening: corrupt/truncated `.lru` markers, orphaned
/// markers whose artifact vanished, and stray files in the kind dirs must
/// all be tolerated — eviction still converges under the bound and loads
/// stay exact-or-recompute.
#[test]
fn eviction_tolerates_mangled_lru_markers_and_vanished_files() {
    use ptxasw::pipeline::{KeyBuilder, StoreKind};
    let dir = tmpdir("lru");
    let bound: u64 = 8 * 1024;
    let payload = |id: u64| -> Vec<u8> {
        let mut rng = ptxasw::util::Rng::new(id | 1);
        (0..700).map(|_| rng.below(256) as u8).collect()
    };
    let key = |id: u64| KeyBuilder::new("lru-test").u64(id).finish();

    let store = DiskStore::open(&dir, bound).unwrap();
    for id in 0..24u64 {
        store.store(StoreKind::Scored, key(id), &payload(id));
    }

    // mangle the bookkeeping: garbage in every .lru marker, one artifact
    // deleted out from under its marker, a stray unparseable file
    let mut lru_files = Vec::new();
    let mut stack = vec![dir.clone()];
    while let Some(d) = stack.pop() {
        let Ok(rd) = std::fs::read_dir(&d) else { continue };
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().and_then(|x| x.to_str()) == Some("lru") {
                lru_files.push(p);
            }
        }
    }
    assert!(!lru_files.is_empty(), "stores must have left touch markers");
    for (i, f) in lru_files.iter().enumerate() {
        std::fs::write(f, if i % 2 == 0 { &b"garbage"[..] } else { &b""[..] }).unwrap();
    }
    if let Some(orphan) = art_files(&dir).first() {
        std::fs::remove_file(orphan).unwrap();
    }
    std::fs::write(dir.join("v7").join("scored").join("stray.bin"), b"noise").unwrap();

    // a fresh handle over the battered dir: open scans, eviction
    // converges, loads stay exact-or-recompute
    let store2 = DiskStore::open(&dir, bound).unwrap();
    store2.evict_to_limit();
    let total: u64 = art_files(&dir)
        .iter()
        .map(|f| std::fs::metadata(f).map(|m| m.len()).unwrap_or(0))
        .sum();
    assert!(total <= bound, "{total} resident bytes > bound {bound}");
    for id in 0..24u64 {
        if let Some(bytes) = store2.load(StoreKind::Scored, key(id)) {
            assert_eq!(bytes, payload(id), "artifact {id} served wrong bytes");
        }
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// CI smoke test: when `RUST_PALLAS_CACHE_DIR` points at a cache
/// directory, run the suite against it. A first (cold) invocation seeds
/// the store; a second invocation of this same test — CI's second
/// `cargo test` — must be served from disk with zero emulations and zero
/// simulations. Skipped when the variable is unset.
#[test]
fn ci_warm_cache_smoke() {
    let Some(dir) = std::env::var_os("RUST_PALLAS_CACHE_DIR") else {
        eprintln!("ci_warm_cache_smoke: RUST_PALLAS_CACHE_DIR unset, skipping");
        return;
    };
    let dir = PathBuf::from(dir);
    let warmed = !art_files(&dir).is_empty();

    let p = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    unwrap_all(run_suite_on(&p, &benches(), &PipelineConfig::default()));
    let s = p.stats();
    println!("{}", report::pipeline_stats(&s));

    if warmed {
        assert!(s.disk.hits > 0, "warmed cache dir must report disk hits");
        assert_eq!(s.stage_count(Stage::Emulate), 0, "zero emulations on warm run");
        assert_eq!(s.stage_count(Stage::Validate), 0, "zero simulations on warm run");
    } else {
        assert!(s.disk.stores > 0, "cold run must seed the store");
    }
}
