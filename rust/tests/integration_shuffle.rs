//! End-to-end semantics preservation: for stencil kernels (with guards,
//! fractional warps, divergence), the shuffle-synthesized PTX must produce
//! bit-identical results to the original on the warp simulator. This is the
//! correctness claim behind the paper's Figure 2 ("PTXASW" bars are valid
//! results; NO LOAD / NO CORNER are not).

use ptxasw::ptx::parser::parse_kernel;
use ptxasw::ptx::printer::print_kernel;
use ptxasw::shuffle::{analyze, synthesize, Variant};
use ptxasw::sim::{run, Allocator, GlobalMem, SimConfig};
use ptxasw::util::{check_cases, Rng};

/// Guarded 1D 3-point stencil (jacobi row): out[i] = a[i-1]+a[i]+a[i+1]
/// for 1 <= i < n-1, with `i = ctaid.x*ntid.x + tid.x + 1`.
const STENCIL3: &str = r#"
.visible .entry s3(.param .u64 out, .param .u64 a, .param .u32 n){
.reg .b32 %r<8>; .reg .b64 %rd<10>; .reg .f32 %f<8>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
add.s32 %r1, %r1, 1;
add.s32 %r6, %r5, -1;
setp.ge.s32 %p1, %r1, %r6;
@%p1 bra $EXIT;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6+-4];
ld.global.nc.f32 %f2, [%rd6];
ld.global.nc.f32 %f3, [%rd6+4];
add.f32 %f4, %f1, %f2;
add.f32 %f5, %f4, %f3;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f5;
$EXIT: ret;
}
"#;

fn run_stencil(src: &str, n: usize, grid: u32, block: u32, input: &[f32]) -> Vec<f32> {
    let k = parse_kernel(src).unwrap();
    let mut mem = GlobalMem::new(1 << 20);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4 * n as u64);
    let a = alloc.alloc(4 * n as u64);
    mem.write_f32s(a, input).unwrap();
    mem.write_f32s(out, &vec![-1.0; n]).unwrap();
    let cfg = SimConfig::new(grid, block, vec![out, a, n as u64]);
    let r = run(&k, &cfg, mem).unwrap();
    r.mem.read_f32s(out, n).unwrap()
}

fn synthesized_src(variant: Variant) -> String {
    let k = parse_kernel(STENCIL3).unwrap();
    let det = analyze(&k).unwrap();
    assert_eq!(det.shuffle_count(), 2, "stencil3 must give 2 shuffles");
    let s = synthesize(&k, &det, variant);
    print_kernel(&s)
}

#[test]
fn full_variant_bit_exact_on_complete_warps() {
    let n = 256;
    let input: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 17.0).collect();
    let orig = run_stencil(STENCIL3, n, 8, 32, &input);
    let synth = run_stencil(&synthesized_src(Variant::Full), n, 8, 32, &input);
    assert_eq!(orig, synth);
}

#[test]
fn full_variant_bit_exact_on_fractional_warps_and_guards() {
    // n chosen so the last warp is fractional and the guard bites mid-warp
    let n = 211;
    let input: Vec<f32> = (0..n).map(|i| ((i * 37 % 101) as f32) * 0.25).collect();
    // block of 48 threads: second warp of each block is fractional
    let orig = run_stencil(STENCIL3, n, 5, 48, &input);
    let synth = run_stencil(&synthesized_src(Variant::Full), n, 5, 48, &input);
    assert_eq!(orig, synth);
}

#[test]
fn uniform_branch_variant_bit_exact() {
    let n = 211;
    let input: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
    let orig = run_stencil(STENCIL3, n, 5, 48, &input);
    let synth = run_stencil(&synthesized_src(Variant::UniformBranch), n, 5, 48, &input);
    assert_eq!(orig, synth);
}

#[test]
fn invalid_variants_differ_but_run() {
    // NO LOAD / NO CORNER are perf probes; they must execute without
    // faulting but are expected to produce different (invalid) interior data
    let n = 128;
    let input: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
    let orig = run_stencil(STENCIL3, n, 4, 32, &input);
    for v in [Variant::NoLoad, Variant::NoCorner] {
        let out = run_stencil(&synthesized_src(v), n, 4, 32, &input);
        assert_eq!(out.len(), orig.len());
        assert_ne!(orig, out, "{} should corrupt corner lanes", v.name());
    }
}

/// Property: random 1D stencil footprints stay bit-exact after synthesis.
#[test]
fn prop_random_stencils_preserved() {
    check_cases("random-stencil-synthesis", 25, |rng: &mut Rng| {
        // random footprint of 2..5 taps within [-3, +3]
        let ntaps = 2 + rng.below(4) as usize;
        let mut offs: Vec<i64> = Vec::new();
        while offs.len() < ntaps {
            let o = rng.range_i64(-3, 3);
            if !offs.contains(&o) {
                offs.push(o);
            }
        }
        offs.sort();

        // build the PTX: i = ctaid*ntid + tid + 3 (halo), guard i < n-3
        let mut body = String::new();
        let mut sums = String::new();
        for (t, o) in offs.iter().enumerate() {
            body.push_str(&format!(
                "ld.global.nc.f32 %f{}, [%rd6+{}];\n",
                t + 1,
                o * 4
            ));
            if t == 0 {
                sums.push_str(&format!("mov.f32 %facc, %f1;\n"));
            } else {
                sums.push_str(&format!("add.f32 %facc, %facc, %f{};\n", t + 1));
            }
        }
        let src = format!(
            r#"
.visible .entry rs(.param .u64 out, .param .u64 a, .param .u32 n){{
.reg .b32 %r<8>; .reg .b64 %rd<10>; .reg .f32 %f<10>; .reg .f32 %facc<1>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
add.s32 %r1, %r1, 3;
add.s32 %r6, %r5, -3;
setp.ge.s32 %p1, %r1, %r6;
@%p1 bra $EXIT;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd6, %rd3, %rd5;
{body}{sums}add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %facc;
$EXIT: ret;
}}
"#
        );
        let k = parse_kernel(&src).unwrap();
        let det = analyze(&k).unwrap();
        // ntaps loads of one array at constant offsets: all but the first
        // are coverable
        assert_eq!(det.shuffle_count(), ntaps - 1, "offsets {offs:?}");

        let n = 96 + rng.below(64) as usize;
        let input: Vec<f32> = (0..n).map(|_| rng.f32() * 8.0 - 4.0).collect();
        let block = *rng.pick(&[32u32, 48, 64]);
        let grid = (n as u32).div_ceil(block);
        let orig = run_stencil(&src, n, grid, block, &input);
        for v in [Variant::Full, Variant::UniformBranch] {
            let s = synthesize(&k, &det, v);
            let ssrc = print_kernel(&s);
            let got = run_stencil(&ssrc, n, grid, block, &input);
            assert_eq!(orig, got, "variant {} offsets {offs:?}", v.name());
        }
    });
}

/// Paper §6: the synthesis also works on shared-memory loads. A kernel
/// stages a tile through shared memory and reads 3 neighbours back; with
/// `include_shared` the detector covers two of those loads, and the
/// synthesized kernel stays bit-exact.
#[test]
fn shared_memory_loads_covered_when_enabled() {
    use ptxasw::emu::emulate;
    use ptxasw::shuffle::{detect, DetectOpts};

    const SRC: &str = r#"
.visible .entry sh(.param .u64 out, .param .u64 a){
.reg .b32 %r<8>; .reg .b64 %rd<10>; .reg .f32 %f<8>; .reg .pred %p<2>;
.shared .align 4 .b8 tile[512];
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
// stage: tile[tid+1] = a[tid] (halo cells left untouched → zero)
ld.global.nc.f32 %f1, [%rd6];
mov.u32 %r5, %r4;
add.s32 %r5, %r5, 1;
mul.wide.s32 %rd7, %r5, 4;
st.shared.f32 [%rd7], %f1;
bar.sync 0;
// read 3 shared neighbours around tid+1
ld.shared.f32 %f2, [%rd7+-4];
ld.shared.f32 %f3, [%rd7];
ld.shared.f32 %f4, [%rd7+4];
add.f32 %f5, %f2, %f3;
add.f32 %f6, %f5, %f4;
add.s64 %rd8, %rd4, %rd5;
st.global.f32 [%rd8], %f6;
ret;
}
"#;
    let k = parse_kernel(SRC).unwrap();
    let res = emulate(&k).unwrap();

    // default: shared loads ignored
    let det0 = detect(&k, &res, DetectOpts::default());
    assert_eq!(det0.shuffle_count(), 0);

    // enabled: the two neighbour loads are covered (N = ±1... N=1 and 2
    // relative to the first shared load)
    let det = detect(
        &k,
        &res,
        DetectOpts {
            include_shared: true,
            ..Default::default()
        },
    );
    assert_eq!(det.shuffle_count(), 2, "{:?}", det.chosen);

    // semantics preserved on the simulator
    let run_one = |kernel: &ptxasw::ptx::ast::Kernel| -> Vec<f32> {
        let mut mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let out = alloc.alloc(4 * 32);
        let a = alloc.alloc(4 * 32);
        let vals: Vec<f32> = (0..32).map(|i| (i as f32) * 1.5 - 7.0).collect();
        mem.write_f32s(a, &vals).unwrap();
        let cfg = SimConfig::new(1, 32, vec![out, a]);
        let r = run(kernel, &cfg, mem).unwrap();
        r.mem.read_f32s(out, 32).unwrap()
    };
    let orig = run_one(&k);
    let sk = synthesize(&k, &det, Variant::Full);
    let got = run_one(&sk);
    assert_eq!(orig, got, "shared-memory synthesis must be bit-exact");
}
