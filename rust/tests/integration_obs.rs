//! Observability integration (ISSUE PR 9).
//!
//! Pins the two contracts the tracing subsystem makes:
//!
//! 1. **Tracing never changes results.** A traced suite run produces
//!    bit-identical artifacts (printed PTX, simulator stats, modelled
//!    cycles) to an untraced run of the same benchmarks.
//! 2. **The export is Perfetto-loadable.** Every event in the Chrome
//!    trace-event document is well-formed: `ph` is `X` or `i`, a `dur`
//!    field appears exactly on complete events, and the whole document
//!    round-trips through the zero-dep JSON codec.
//!
//! Plus: spans cover every pipeline stage, store operations emit spans
//! through the [`Vfs`] seam (including injected-fault outcomes), and a
//! disabled tracer records nothing across a full run.

use ptxasw::coordinator::{run_suite_on, BenchResult, PipelineConfig, PipelineError};
use ptxasw::obs::{ArgVal, TracePhase, Tracer, METRICS_VERSION};
use ptxasw::pipeline::{DiskStore, KeyBuilder, Pipeline, STAGES, STORE_KINDS};
use ptxasw::ptx::printer::print_kernel;
use ptxasw::suite::{by_name, shared_suite, suite, Benchmark};
use ptxasw::util::{FaultFs, FaultKind, FaultOp, FaultRule, Json, RealFs, Vfs};
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ptxasw-obs-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn full_suite() -> Vec<Benchmark> {
    suite().into_iter().chain(shared_suite()).collect()
}

fn unwrap_all(results: Vec<Result<BenchResult, PipelineError>>) -> Vec<BenchResult> {
    results
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("benchmark failed: {e}")))
        .collect()
}

/// Bit-exact equality over everything a run produces: detection, the
/// synthesized kernel text, simulator stats, validity and modelled cycles.
fn assert_identical(a: &[BenchResult], b: &[BenchResult]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.detection.chosen, y.detection.chosen, "{}", x.name);
        let (px, py) = (print_kernel(&x.kernel), print_kernel(&y.kernel));
        assert_eq!(px, py, "{}: synthesized PTX diverged under tracing", x.name);
        assert_eq!(x.baseline.sim_stats, y.baseline.sim_stats, "{}", x.name);
        assert_eq!(x.baseline.valid, y.baseline.valid);
        for ((xv, xo), (yv, yo)) in x.variants.iter().zip(&y.variants) {
            assert_eq!(xv, yv);
            assert_eq!(xo.sim_stats, yo.sim_stats, "{} {}", x.name, xv.name());
            assert_eq!(xo.valid, yo.valid, "{} {}", x.name, xv.name());
            for (xr, yr) in xo.reports.iter().zip(&yo.reports) {
                let (cx, cy) = (xr.effective_cycles, yr.effective_cycles);
                assert_eq!(cx.to_bits(), cy.to_bits(), "{}: cycles diverged", x.name);
            }
        }
    }
}

/// The tentpole differential: an enabled tracer observes the entire suite
/// (classic + shared families) without perturbing a single artifact bit,
/// and the recorded spans cover every one of the eight pipeline stages.
#[test]
fn tracing_never_changes_results_and_spans_cover_every_stage() {
    let benches = full_suite();
    let cfg = PipelineConfig::default();

    let plain = Pipeline::new();
    let untraced = unwrap_all(run_suite_on(&plain, &benches, &cfg));
    let purity = plain.tracer().is_empty();
    assert!(purity, "a disabled tracer must record nothing over a full run");

    let tracer = Arc::new(Tracer::enabled());
    let traced_p = Pipeline::new().with_tracer(tracer.clone());
    let traced = unwrap_all(run_suite_on(&traced_p, &benches, &cfg));

    assert_identical(&untraced, &traced);

    let events = tracer.events();
    assert!(!events.is_empty());
    assert_eq!(tracer.dropped(), 0, "default ring must hold a suite run");
    for stage in STAGES {
        let covered = events
            .iter()
            .any(|e| e.name == stage.span_name() && e.phase == TracePhase::Complete);
        assert!(covered, "missing a complete span for {}", stage.span_name());
    }
    // the engine-selection decision is recorded per simulation, and the
    // cache-provenance instants ride along with their artifact family
    let names: Vec<&str> = events.iter().map(|e| e.name).collect();
    assert!(names.contains(&"sim.engine"), "{names:?}");
    assert!(names.contains(&"artifact.emulated"), "{names:?}");
    assert!(names.contains(&"artifact.workload"), "{names:?}");
}

/// The Chrome export is structurally valid for Perfetto: parseable by the
/// same codec that wrote it, `traceEvents` non-empty, every event carries
/// name/cat/ph/ts/pid/tid, `ph ∈ {X, i}`, and `dur` appears iff `ph == X`.
#[test]
fn chrome_export_is_perfetto_valid() {
    let tracer = Arc::new(Tracer::enabled());
    let p = Pipeline::new().with_tracer(tracer.clone());
    let b = by_name("gradient").unwrap();
    let cfg = PipelineConfig::default();
    unwrap_all(run_suite_on(&p, std::slice::from_ref(&b), &cfg));

    let rendered = tracer.export_chrome().render();
    let doc = Json::parse(&rendered).expect("export must be valid JSON");
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty());
    for e in events {
        assert!(e.get("name").and_then(Json::as_str).is_some(), "{e:?}");
        assert!(e.get("cat").and_then(Json::as_str).is_some(), "{e:?}");
        assert!(e.get("ts").is_some(), "{e:?}");
        assert_eq!(e.get("pid").and_then(Json::as_u64), Some(1), "{e:?}");
        assert!(e.get("tid").and_then(Json::as_u64).is_some(), "{e:?}");
        let ph = e.get("ph").and_then(Json::as_str).expect("ph");
        match ph {
            "X" => assert!(e.get("dur").is_some(), "X without dur: {e:?}"),
            "i" => {
                assert!(e.get("dur").is_none(), "instant with dur: {e:?}");
                assert_eq!(e.get("s").and_then(Json::as_str), Some("t"), "{e:?}");
            }
            other => panic!("unexpected phase {other:?}: {e:?}"),
        }
    }
    let other = doc.get("otherData").expect("otherData");
    assert_eq!(other.get("dropped_events").and_then(Json::as_u64), Some(0));
}

/// Store operations emit provenance spans through the [`Vfs`] seam — so
/// injected IO faults surface as `failed`/`miss` outcomes in the trace,
/// exactly where the fault-injection suite drives them.
#[test]
fn store_ops_emit_spans_through_the_vfs_seam() {
    let dir = tmpdir("store");
    let fs = FaultFs::new(Arc::new(RealFs));
    let vfs: Arc<dyn Vfs> = fs.clone();
    let tracer = Arc::new(Tracer::enabled());
    let mut store = DiskStore::open_on(vfs, &dir, 1 << 20).unwrap();
    store.set_tracer(tracer.clone());
    let kind = STORE_KINDS[0];
    let key = |n: u64| KeyBuilder::new("obs-store").u64(n).finish();

    store.store(kind, key(1), b"payload-one");
    assert!(store.load(kind, key(1)).is_some());
    assert!(store.load(kind, key(2)).is_none());

    // one injected write failure: the store degrades and the span says so
    fs.push_rules(&[FaultRule {
        op: FaultOp::Write,
        nth: 0,
        kind: FaultKind::Error,
    }]);
    fs.arm(true);
    store.store(kind, key(3), b"payload-three");
    fs.arm(false);

    store.evict_to_limit();

    let events = tracer.events();
    let outcomes: Vec<(&str, String)> = events
        .iter()
        .map(|e| {
            let outcome = e.args.iter().find_map(|(k, v)| match v {
                ArgVal::Str(s) if *k == "outcome" => Some(s.clone()),
                _ => None,
            });
            (e.name, outcome.unwrap_or_default())
        })
        .collect();
    let has = |name: &str, outcome: &str| outcomes.iter().any(|(n, o)| *n == name && o == outcome);
    assert!(has("store.store", "stored"), "{outcomes:?}");
    assert!(has("store.load", "hit"), "{outcomes:?}");
    assert!(has("store.load", "miss"), "{outcomes:?}");
    assert!(has("store.store", "failed"), "{outcomes:?}");
    let evicted = events
        .iter()
        .any(|e| e.name == "store.evict" && e.phase == TracePhase::Complete);
    assert!(evicted, "eviction sweep records a complete span");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The unified metrics snapshot folds the cache, stage, engine and store
/// stat families into one versioned registry with stable dotted names.
#[test]
fn metrics_snapshot_unifies_the_stat_families() {
    let p = Pipeline::new();
    let b = by_name("vecadd").unwrap();
    let cfg = PipelineConfig::default();
    unwrap_all(run_suite_on(&p, std::slice::from_ref(&b), &cfg));

    let m = p.metrics();
    assert_eq!(m.version, METRICS_VERSION);
    assert!(m.get("cache.emulate.misses").unwrap() >= 1);
    assert!(m.get("stage.emulate.runs").unwrap() >= 1);
    assert!(m.get("stage.validate.runs").unwrap() >= 1);
    assert_eq!(m.get("store.enabled"), Some(0), "no disk store attached");
    assert_eq!(m.get("trace.dropped"), Some(0));
    let lat = m.get_hist("stage.emulate.latency").expect("stage histogram");
    assert!(lat.count >= 1);
    let runs = m.get("stage.emulate.runs").unwrap();
    assert_eq!(lat.count, runs, "histogram count mirrors the run counter");

    // both render paths carry the registry
    let table = m.render_table();
    assert!(table.contains("cache.emulate.misses"), "{table}");
    assert!(table.contains("stage.emulate.latency"), "{table}");
    let doc = Json::parse(&m.to_json().render()).expect("metrics JSON parses");
    assert_eq!(doc.get("metrics_version").and_then(Json::as_u64), Some(1));
    let counters = doc.get("counters").expect("counters object");
    assert!(counters.get("stage.emulate.runs").is_some());
}
