//! Fault-injection property suite for the disk store (ISSUE PR 8).
//!
//! Every filesystem operation the store performs is routed through the
//! [`Vfs`] seam, so these tests drive the whole pipeline through every
//! injected failure class — failed opens/reads/writes/renames/deletes,
//! short (torn) writes, simulated ENOSPC, crash-point truncation — and
//! assert the invariants the store guarantees:
//!
//! 1. any failure degrades to recompute with **bit-exact** results,
//! 2. never a panic,
//! 3. never a poisoned cache entry (a later load returns the stored
//!    bytes exactly or nothing at all),
//! 4. a subsequent no-fault run heals the directory.

use ptxasw::coordinator::{run_suite_on, BenchResult, PipelineConfig, PipelineError};
use ptxasw::pipeline::{DiskStore, KeyBuilder, Pipeline, StoreKind, STORE_KINDS};
use ptxasw::ptx::ContentHash;
use ptxasw::suite::{by_name, Benchmark};
use ptxasw::util::{FaultFs, FaultKind, FaultOp, FaultRule, RealFs, Vfs};
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ptxasw-fault-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn key(n: u64) -> ContentHash {
    KeyBuilder::new("fault-suite").u64(n).finish()
}

fn payload(n: u64, len: usize) -> Vec<u8> {
    let mut rng = ptxasw::util::Rng::new(n.wrapping_mul(0x9E37_79B9) | 1);
    (0..len).map(|_| rng.below(256) as u8).collect()
}

const FAULT_KINDS: [FaultKind; 6] = [
    FaultKind::Error,
    FaultKind::Enospc,
    FaultKind::Torn(3),
    FaultKind::Torn(21),
    FaultKind::Crash(3),
    FaultKind::Crash(21),
];

/// The exhaustive grid: for every store kind, every VFS operation class
/// and every fault flavor, one injected fault mid-traffic must leave the
/// store serving exact bytes or nothing — and a clean retry must fully
/// recover.
#[test]
fn every_fault_class_degrades_to_exact_or_recompute_for_every_kind() {
    let root = tmpdir("grid");
    let mut case = 0u64;
    for kind in STORE_KINDS {
        for op in ptxasw::util::vfs::FAULT_OPS {
            for fk in FAULT_KINDS {
                case += 1;
                let dir = root.join(format!("case-{case}"));
                let fs = FaultFs::new(Arc::new(RealFs));
                let vfs: Arc<dyn Vfs> = fs.clone();
                let store = DiskStore::open_on(vfs, &dir, 1 << 20).unwrap();

                // seed one clean entry, then inject exactly one fault
                let (a, b) = (payload(case, 600), payload(case + 1000, 600));
                store.store(kind, key(1), &a);
                fs.push_rules(&[FaultRule { op, nth: 0, kind: fk }]);
                fs.arm(true);

                // traffic that exercises every op class at least once
                store.store(kind, key(2), &b);
                let l1 = store.load(kind, key(1));
                let l2 = store.load(kind, key(2));
                store.evict_to_limit();
                assert!(
                    l1.is_none() || l1.as_deref() == Some(a.as_slice()),
                    "case {case} ({kind:?} {op:?} {fk:?}): load(1) returned wrong bytes"
                );
                assert!(
                    l2.is_none() || l2.as_deref() == Some(b.as_slice()),
                    "case {case} ({kind:?} {op:?} {fk:?}): load(2) returned wrong bytes"
                );

                // the fault is one-shot; a clean retry must fully recover
                fs.arm(false);
                store.store(kind, key(2), &b);
                assert_eq!(
                    store.load(kind, key(2)).as_deref(),
                    Some(b.as_slice()),
                    "case {case} ({kind:?} {op:?} {fk:?}): clean re-store must heal"
                );
            }
        }
    }
    assert!(case > 0);
    let _ = std::fs::remove_dir_all(&root);
}

/// Crash-point truncation specifically: a write that *reports success*
/// but persisted a prefix (the rename landed a truncated file) must be
/// detected on load, discarded, and counted — never served.
#[test]
fn crash_truncated_artifacts_are_discarded_on_load_and_swept_heals() {
    let dir = tmpdir("crash");
    let fs = FaultFs::new(Arc::new(RealFs));
    let vfs: Arc<dyn Vfs> = fs.clone();
    let store = DiskStore::open_on(vfs, &dir, 1 << 20).unwrap();

    let p = payload(7, 900);
    for (i, k) in [3usize, 40, 200].iter().enumerate() {
        let id = 10 + i as u64;
        fs.push_rules(&[FaultRule {
            op: FaultOp::Write,
            nth: 0,
            kind: FaultKind::Crash(*k),
        }]);
        fs.arm(true);
        store.store(StoreKind::Scored, key(id), &p);
        fs.arm(false);
        assert_eq!(
            store.load(StoreKind::Scored, key(id)),
            None,
            "crash at byte {k}: the truncated file must never be served"
        );
    }
    assert!(store.snapshot().corrupt >= 3, "each truncation is counted");

    // a clean rerun stores and serves normally over the same dir
    store.store(StoreKind::Scored, key(10), &p);
    assert_eq!(store.load(StoreKind::Scored, key(10)).as_deref(), Some(p.as_slice()));

    let _ = std::fs::remove_dir_all(&dir);
}

// -- whole-pipeline property ------------------------------------------------

fn benches() -> Vec<Benchmark> {
    // one classic and one shared-memory benchmark: together their suite
    // runs persist all six artifact kinds
    ["vecadd", "tiledreduce"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect()
}

fn unwrap_all(results: Vec<Result<BenchResult, PipelineError>>) -> Vec<BenchResult> {
    results
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("benchmark failed under faults: {e}")))
        .collect()
}

fn assert_same_results(a: &[BenchResult], b: &[BenchResult]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.detection.chosen, y.detection.chosen);
        assert_eq!(x.baseline.valid, y.baseline.valid);
        for ((xv, xo), (yv, yo)) in x.variants.iter().zip(&y.variants) {
            assert_eq!(xv, yv);
            assert_eq!(xo.valid, yo.valid, "{}: validity diverged", x.name);
            for (xr, yr) in xo.reports.iter().zip(&yo.reports) {
                assert_eq!(
                    xr.effective_cycles.to_bits(),
                    yr.effective_cycles.to_bits(),
                    "{}: modelled cycles diverged under faults",
                    x.name
                );
            }
        }
    }
}

/// The headline property: a full pipeline run under seeded random fault
/// injection produces results bit-exact with a cache-less run, panics
/// never, and the battered cache directory is healed by `verify(heal)` —
/// afterwards a clean run over it agrees again and the store audits
/// clean.
#[test]
fn randomized_fault_runs_are_bit_exact_and_the_dir_heals() {
    let cfg = PipelineConfig {
        threads: 1,
        ..PipelineConfig::default()
    };
    let bs = benches();
    let clean = unwrap_all(run_suite_on(&Pipeline::new(), &bs, &cfg));

    for seed in [1u64, 7, 23] {
        let dir = tmpdir(&format!("rand-{seed}"));
        let fs = FaultFs::new(Arc::new(RealFs));
        let vfs: Arc<dyn Vfs> = fs.clone();
        // open clean (an injector firing during mkdir would just fail
        // open, which is the CLI's warning path, not this property)
        let store = DiskStore::open_on(vfs, &dir, 1 << 22).unwrap();
        fs.randomize(seed, 6);
        fs.arm(true);

        let p = Pipeline::new().with_disk(store);
        let faulted = unwrap_all(run_suite_on(&p, &bs, &cfg));
        assert_same_results(&clean, &faulted);
        assert!(
            fs.injected() > 0,
            "seed {seed}: the run must actually have seen faults (tune the rate)"
        );
        fs.arm(false);

        // heal pass: every surviving artifact decodes or is removed
        let store2 = DiskStore::open(&dir, 1 << 22).unwrap();
        store2.verify(true);
        let audit = store2.verify(false);
        assert_eq!(
            audit.bad, 0,
            "seed {seed}: the healed dir must audit clean, found {:?}",
            audit.bad_paths
        );

        // and a clean run over the healed dir agrees with the baseline
        let p2 = Pipeline::new().with_disk(store2);
        let healed = unwrap_all(run_suite_on(&p2, &bs, &cfg));
        assert_same_results(&clean, &healed);

        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// ENOSPC mid-run is survivable: every write fails, nothing persists,
/// results still come out bit-exact (the store is an accelerator, not a
/// dependency).
#[test]
fn enospc_on_every_write_still_computes_exact_results() {
    let cfg = PipelineConfig {
        threads: 1,
        ..PipelineConfig::default()
    };
    let bs = benches();
    let clean = unwrap_all(run_suite_on(&Pipeline::new(), &bs, &cfg));

    let dir = tmpdir("enospc");
    let fs = FaultFs::new(Arc::new(RealFs));
    let vfs: Arc<dyn Vfs> = fs.clone();
    let store = DiskStore::open_on(vfs, &dir, 1 << 22).unwrap();
    // exhaust the "disk" for the whole run: every write from now on fails
    let rules: Vec<FaultRule> = (0..10_000)
        .map(|n| FaultRule {
            op: FaultOp::Write,
            nth: n,
            kind: FaultKind::Enospc,
        })
        .collect();
    fs.push_rules(&rules);
    fs.arm(true);
    let p = Pipeline::new().with_disk(store);
    let out = unwrap_all(run_suite_on(&p, &bs, &cfg));
    assert_same_results(&clean, &out);
    assert!(fs.injected() > 0, "the run writes artifacts, so faults must fire");

    let _ = std::fs::remove_dir_all(&dir);
}
