//! Differential and divergence tests for the two simulator engines.
//!
//! The decoded micro-op engine (serial and parallel) must be bit-identical
//! to the reference AST walker on every observable: final global memory,
//! stats, and the block-(0,0,0) issue trace. Divergence control flow is
//! additionally pinned to hand-computed lane tables so a bug shared by
//! both engines cannot hide.

use ptxasw::coordinator::sim_sizes;
use ptxasw::ptx::parser::parse_kernel;
use ptxasw::ptx::Kernel;
use ptxasw::sim::{
    run, run_reference, Allocator, BarrierCause, GlobalMem, SimConfig, SimError, SimResult,
};
use ptxasw::suite;
use ptxasw::util::check_cases;

/// The decoded engine's path-selection matrix: (superblocks, vector).
/// `vector` is inert without the `simd` cargo feature, but running the
/// configuration anyway keeps the matrix identical across builds.
const ENGINES: [(bool, bool, &str); 4] = [
    (false, false, "scalar"),
    (true, false, "superblock"),
    (false, true, "vector"),
    (true, true, "fused"),
];

/// Run all engines (reference, then every decoded path configuration on
/// 1, 2 and 8 workers) and assert bit-identical results; returns the
/// decoded result.
fn engines_agree(k: &Kernel, cfg: &SimConfig, mem: GlobalMem) -> SimResult {
    let reference = run_reference(k, cfg, mem.clone()).expect("reference run");
    for (superblocks, vector, name) in ENGINES {
        for threads in [1usize, 2, 8] {
            let mut c = cfg.clone();
            c.sim_threads = threads;
            c.superblocks = superblocks;
            c.vector = vector;
            let r = run(k, &c, mem.clone()).expect("decoded run");
            assert_eq!(
                reference.mem, r.mem,
                "GlobalMem diverged ({name}, {threads} threads)"
            );
            assert_eq!(
                reference.stats, r.stats,
                "stats diverged ({name}, {threads} threads)"
            );
            assert_eq!(
                reference.trace, r.trace,
                "trace diverged ({name}, {threads} threads)"
            );
        }
    }
    run(k, cfg, mem).unwrap()
}

/// Every engine configuration must fail with the same barrier-divergence
/// shape.
fn engines_agree_on_barrier_error(k: &Kernel, cfg: &SimConfig, mem: GlobalMem) -> SimError {
    let e_ref = run_reference(k, cfg, mem.clone()).expect_err("reference must fail");
    for (superblocks, vector, name) in ENGINES {
        for threads in [1usize, 2, 8] {
            let mut c = cfg.clone();
            c.sim_threads = threads;
            c.superblocks = superblocks;
            c.vector = vector;
            let e = run(k, &c, mem.clone()).expect_err("decoded must fail");
            match (&e_ref, &e) {
                (
                    SimError::BarrierDivergence {
                        block: b1,
                        id: i1,
                        cause: c1,
                    },
                    SimError::BarrierDivergence {
                        block: b2,
                        id: i2,
                        cause: c2,
                    },
                ) => {
                    assert_eq!(
                        (b1, i1, c1),
                        (b2, i2, c2),
                        "error shape diverged ({name}, {threads} threads)"
                    );
                }
                other => panic!("engines disagree on the error: {other:?}"),
            }
        }
    }
    e_ref
}

/// If/else diamond: lanes 0–15 take the `bra`, 16–31 fall through, and
/// everyone reconverges (lowest-pc-first) for a common tail.
#[test]
fn diamond_reconvergence_lane_table() {
    let k = parse_kernel(
        r#"
.visible .entry diamond(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $THEN;
mul.lo.s32 %r2, %r1, 3;
bra $JOIN;
$THEN:
add.s32 %r2, %r1, 100;
$JOIN:
add.s32 %r2, %r2, 1;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
st.global.b32 [%rd3], %r2;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(128);
    let mut cfg = SimConfig::new(1, 32, vec![out]);
    cfg.record_trace = true;
    let r = engines_agree(&k, &cfg, mem);

    let vals = r.mem.read_u32s(out, 32).unwrap();
    for t in 0..32u32 {
        let expect = if t < 16 { t + 100 + 1 } else { t * 3 + 1 };
        assert_eq!(vals[t as usize], expect, "lane {t}");
    }
    assert_eq!(r.stats.divergent_branches, 1, "only the guarded bra diverges");
    // the else-path executes first (its pc is lower), then the then-path,
    // and the tail reconverges to the full warp
    let trace = &r.trace[0];
    assert!(trace.iter().any(|e| e.active == 0xFFFF_0000));
    assert!(trace.iter().any(|e| e.active == 0x0000_FFFF));
    let tail = trace.last().unwrap();
    assert_eq!(tail.active, 0xFFFF_FFFF, "reconverged for the ret");
}

/// Per-lane loop trip counts (`(tid & 3) + 1`): looping lanes run before
/// the exited lanes' store (lowest pc first), and the store issues once
/// for the whole warp.
#[test]
fn loop_divergence_lane_table() {
    let k = parse_kernel(
        r#"
.visible .entry lp(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
and.b32 %r2, %r1, 3;
mov.u32 %r3, 0;
mov.u32 %r4, 0;
$LOOP:
add.s32 %r4, %r4, %r1;
add.s32 %r3, %r3, 1;
setp.le.s32 %p1, %r3, %r2;
@%p1 bra $LOOP;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
st.global.b32 [%rd3], %r4;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(128);
    let mut cfg = SimConfig::new(1, 32, vec![out]);
    cfg.record_trace = true;
    let r = engines_agree(&k, &cfg, mem);
    let vals = r.mem.read_u32s(out, 32).unwrap();
    for t in 0..32u32 {
        assert_eq!(vals[t as usize], t * ((t & 3) + 1), "lane {t}");
    }
    assert!(r.stats.divergent_branches >= 1);
    // every lane stores exactly once, as one warp-level issue
    let stores: Vec<_> = r.trace[0]
        .iter()
        .filter(|e| e.exec == 0xFFFF_FFFF)
        .collect();
    assert!(!stores.is_empty());
    assert_eq!(r.stats.stores, 32);
}

/// Fractional warps (`done: t >= tpb`) and negated-guard predication:
/// block of 37 threads, only odd tids store.
#[test]
fn fractional_warp_and_predicated_off_lanes() {
    let k = parse_kernel(
        r#"
.visible .entry fw(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
and.b32 %r2, %r1, 1;
setp.eq.s32 %p1, %r2, 0;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
@!%p1 st.global.b32 [%rd3], %r1;
ret;
}
"#,
    )
    .unwrap();
    let mut mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4 * 64);
    mem.write_u32s(out, &vec![9999; 64]).unwrap();
    let mut cfg = SimConfig::new(1, 37, vec![out]);
    cfg.record_trace = true;
    let r = engines_agree(&k, &cfg, mem);
    let vals = r.mem.read_u32s(out, 64).unwrap();
    for t in 0..64u32 {
        let expect = if t < 37 && t % 2 == 1 { t } else { 9999 };
        assert_eq!(vals[t as usize], expect, "lane {t}");
    }
    // 18 odd tids below 37
    assert_eq!(r.stats.stores, 18);
    // two warp streams were traced (37 threads = 1 full + 1 fractional);
    // the second warp's lanes 5..31 never execute anything
    assert_eq!(r.trace.len(), 2);
    assert!(r.trace[1].iter().all(|e| e.active & 0xFFFF_FFE0 == 0));
    // the guarded store issues with a proper exec subset
    let st = r.trace[0]
        .iter()
        .find(|e| e.exec != e.active && e.exec != 0)
        .expect("guarded store event");
    assert_eq!(st.exec, 0xAAAA_AAAA, "odd lanes of warp 0");
}

/// Every block stores to the same word: deterministic last-block-wins
/// value plus a conflict count of `nblocks - 1`, identical on every
/// engine and thread count.
#[test]
fn cross_block_write_conflicts_are_counted() {
    let k = parse_kernel(
        r#"
.visible .entry clash(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %ctaid.x;
st.global.b32 [%rd1], %r1;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4);
    let cfg = SimConfig::new(4, 1, vec![out]);
    let r = engines_agree(&k, &cfg, mem);
    assert_eq!(r.mem.read_u32s(out, 1).unwrap()[0], 3, "launch order wins");
    assert_eq!(r.stats.cross_block_write_conflicts, 3);
}

/// Disjoint per-block writes must not count as conflicts.
#[test]
fn disjoint_block_writes_do_not_conflict() {
    let k = parse_kernel(
        r#"
.visible .entry dis(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<6>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %ctaid.x;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
st.global.b32 [%rd3], %r1;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4 * 8);
    let cfg = SimConfig::new(8, 1, vec![out]);
    let r = engines_agree(&k, &cfg, mem);
    assert_eq!(
        r.mem.read_u32s(out, 8).unwrap(),
        (0..8).collect::<Vec<u32>>()
    );
    assert_eq!(r.stats.cross_block_write_conflicts, 0);
}

/// An unknown shared variable is an `UnknownVar` on both engines (the
/// decoded engine reports it eagerly at decode time).
#[test]
fn unknown_shared_var_same_error_on_both_engines() {
    let k = parse_kernel(
        r#"
.visible .entry sv(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
mov.u64 %rd1, ghost;
ret;
}
"#,
    )
    .unwrap();
    let cfg = SimConfig::new(1, 1, vec![0x1000]);
    let e1 = run_reference(&k, &cfg, GlobalMem::new(64)).unwrap_err();
    let e2 = run(&k, &cfg, GlobalMem::new(64)).unwrap_err();
    for e in [e1, e2] {
        assert!(
            matches!(&e, SimError::UnknownVar(v) if v == "ghost"),
            "want UnknownVar(ghost), got {e:?}"
        );
        assert!(e.to_string().contains("unknown shared variable"));
    }
}

/// Randomized differential: suite benchmarks with randomized seeds, run
/// through every engine, must agree bit-for-bit — and the baseline
/// kernel's output must match the workload's bit-exact CPU reference.
#[test]
fn randomized_suite_workloads_differential() {
    let benches = suite::suite();
    check_cases("sim-differential", 6, |rng| {
        for _ in 0..3 {
            let b = &benches[rng.below(benches.len() as u64) as usize];
            let (nx, ny, nz) = sim_sizes(b);
            let seed = rng.next_u64();
            let w = suite::workload(b, nx, ny, nz, seed);
            let mut cfg = w.cfg.clone();
            cfg.record_trace = true;
            let r = engines_agree(&w.kernel, &cfg, w.mem.clone());
            let out = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
            assert_eq!(out.len(), w.expected.len());
            for (i, (a, e)) in out.iter().zip(&w.expected).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    e.to_bits(),
                    "{}[{i}] diverged from the CPU reference (seed {seed})",
                    b.name
                );
            }
            assert_eq!(r.stats.cross_block_write_conflicts, 0, "{}", b.name);
        }
    });
}

/// Two-warp shared-memory exchange with a hand-computed phase table:
/// every thread stages its tid into `sm[tid]`, one `bar.sync`, then reads
/// its cross-warp partner `sm[tid ^ 32]` — warp 0 reads bytes warp 1
/// wrote and vice versa, which is only correct under real barrier
/// semantics (the serialized-warp model would read zeros for warp 0).
const XCHG: &str = r#"
.visible .entry xch(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<8>;
.shared .align 4 .b8 sm[256];
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
mov.u64 %rd2, sm;
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd4, %rd2, %rd3;
st.shared.b32 [%rd4], %r1;
bar.sync 0;
xor.b32 %r2, %r1, 32;
mul.wide.s32 %rd5, %r2, 4;
add.s64 %rd6, %rd2, %rd5;
ld.shared.b32 %r3, [%rd6];
mov.u32 %r4, %ctaid.x;
mov.u32 %r5, %ntid.x;
mad.lo.s32 %r4, %r4, %r5, %r1;
mul.wide.s32 %rd7, %r4, 4;
add.s64 %rd3, %rd1, %rd7;
st.global.b32 [%rd3], %r3;
ret;
}
"#;

#[test]
fn two_warp_shared_exchange_phase_table() {
    let k = parse_kernel(XCHG).unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4 * 128);
    let mut cfg = SimConfig::new(2, 64, vec![out]);
    cfg.record_trace = true;
    let r = engines_agree(&k, &cfg, mem);
    let vals = r.mem.read_u32s(out, 128).unwrap();
    for blk in 0..2u32 {
        for t in 0..64u32 {
            assert_eq!(
                vals[(blk * 64 + t) as usize],
                t ^ 32,
                "block {blk} lane {t}: cross-warp partner value"
            );
        }
    }
    // 2 warps × 1 barrier × 2 blocks arrivals; one release per block
    assert_eq!(r.stats.barriers, 4);
    assert_eq!(r.stats.barrier_phases, 2);
    // trace: both warps of block 0 recorded the bar.sync issue (stmt 7)
    assert_eq!(r.trace.len(), 2);
    for w in 0..2 {
        assert!(
            r.trace[w].iter().any(|e| e.stmt == 7 && e.exec == u32::MAX),
            "warp {w} must trace its full-warp barrier arrival"
        );
    }
}

/// A warp retiring while its sibling waits at a barrier is a hard
/// `BarrierDivergence { cause: Exit }` on every engine.
#[test]
fn warp_exit_while_others_wait_is_barrier_divergence() {
    let k = parse_kernel(
        r#"
.visible .entry bx(.param .u64 out){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, %tid.x;
setp.ge.s32 %p1, %r1, 32;
@%p1 bra $EXIT;
bar.sync 0;
$EXIT: ret;
}
"#,
    )
    .unwrap();
    let cfg = SimConfig::new(1, 64, vec![0x1000]);
    let e = engines_agree_on_barrier_error(&k, &cfg, GlobalMem::new(1 << 12));
    match e {
        SimError::BarrierDivergence { block, id, cause } => {
            assert_eq!((block, id, cause), (0, 0, BarrierCause::Exit));
        }
        other => panic!("got {other:?}"),
    }
}

/// Divergent lanes reaching a barrier (half the warp branched around it)
/// is a hard `BarrierDivergence { cause: Divergence }`.
#[test]
fn divergent_lanes_at_barrier_is_barrier_divergence() {
    let k = parse_kernel(
        r#"
.visible .entry bd(.param .u64 out){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $SKIP;
bar.sync 0;
$SKIP: ret;
}
"#,
    )
    .unwrap();
    let cfg = SimConfig::new(1, 32, vec![0x1000]);
    let e = engines_agree_on_barrier_error(&k, &cfg, GlobalMem::new(1 << 12));
    match e {
        SimError::BarrierDivergence { cause, .. } => {
            assert_eq!(cause, BarrierCause::Divergence);
        }
        other => panic!("got {other:?}"),
    }
}

/// Warps waiting at *different* barrier ids is a hard mismatch error.
#[test]
fn mismatched_barrier_ids_are_barrier_divergence() {
    let k = parse_kernel(
        r#"
.visible .entry bm(.param .u64 out){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, %tid.x;
setp.ge.s32 %p1, %r1, 32;
@%p1 bra $B1;
bar.sync 0;
bra $END;
$B1:
bar.sync 1;
$END: ret;
}
"#,
    )
    .unwrap();
    let cfg = SimConfig::new(1, 64, vec![0x1000]);
    let e = engines_agree_on_barrier_error(&k, &cfg, GlobalMem::new(1 << 12));
    match e {
        SimError::BarrierDivergence { cause, .. } => {
            assert_eq!(cause, BarrierCause::IdMismatch { other: 1 });
        }
        other => panic!("got {other:?}"),
    }
}

/// `bar.sync id, cnt` with a non-full-block count is rejected when the
/// barrier executes, identically on both engines.
#[test]
fn partial_block_barrier_count_is_rejected() {
    let k = parse_kernel(
        r#"
.visible .entry bc(.param .u64 out){
.reg .b32 %r<4>;
bar.sync 0, 32;
ret;
}
"#,
    )
    .unwrap();
    let cfg = SimConfig::new(1, 64, vec![0x1000]);
    let e = engines_agree_on_barrier_error(&k, &cfg, GlobalMem::new(1 << 12));
    match e {
        SimError::BarrierDivergence { cause, .. } => {
            assert_eq!(cause, BarrierCause::PartialCount { cnt: 32, tpb: 64 });
        }
        other => panic!("got {other:?}"),
    }
    // …and a count naming the full block is accepted
    let cfg32 = SimConfig::new(1, 32, vec![0x1000]);
    engines_agree(&k, &cfg32, GlobalMem::new(1 << 12));
}

/// `--detect-races`: the exchange kernel *without* its barrier is an
/// intra-block same-phase race (warp 1 reads bytes warp 0 staged); with
/// the barrier the phases differ and the diagnostic passes.
#[test]
fn intra_block_race_diagnostic() {
    let racy = parse_kernel(&XCHG.replace("bar.sync 0;\n", "")).unwrap();
    let sound = parse_kernel(XCHG).unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4 * 128);
    let mut cfg = SimConfig::new(2, 64, vec![out]);
    cfg.detect_races = true;

    for (tag, r) in [
        ("reference", run_reference(&racy, &cfg, mem.clone())),
        ("decoded", run(&racy, &cfg, mem.clone())),
    ] {
        let e = r.expect_err("missing barrier must be a race");
        match e {
            SimError::IntraBlockRace {
                writer_warp,
                reader_warp,
                phase,
                shared,
                ..
            } => {
                assert_eq!(
                    (writer_warp, reader_warp, phase, shared),
                    (0, 1, 0, true),
                    "{tag}: race shape"
                );
            }
            other => panic!("{tag}: expected IntraBlockRace, got {other:?}"),
        }
    }

    // with the barrier, staging (phase 0) happens-before use (phase 1)
    run_reference(&sound, &cfg, mem.clone()).expect("barrier orders the exchange");
    run(&sound, &cfg, mem.clone()).expect("barrier orders the exchange");
    // and the diagnostic changes nothing observable
    cfg.detect_races = false;
    engines_agree(&sound, &cfg, mem);
}

/// Randomized differential over the shared-memory benchmark family:
/// reference vs decoded vs parallel (1/2/8 workers) bit-identical, and
/// the baseline output matches the bit-exact CPU reference.
#[test]
fn randomized_shared_suite_differential() {
    let benches = suite::shared_suite();
    check_cases("shared-sim-differential", 6, |rng| {
        for b in &benches {
            let (nx, ny, nz) = sim_sizes(b);
            let seed = rng.next_u64();
            let w = suite::workload(b, nx, ny, nz, seed);
            let mut cfg = w.cfg.clone();
            cfg.record_trace = true;
            let r = engines_agree(&w.kernel, &cfg, w.mem.clone());
            assert!(r.stats.barriers > 0, "{}: barriers must execute", b.name);
            assert!(r.stats.barrier_phases > 0, "{}", b.name);
            let out = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
            for (i, (a, e)) in out.iter().zip(&w.expected).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    e.to_bits(),
                    "{}[{i}] diverged from the CPU reference (seed {seed})",
                    b.name
                );
            }
        }
    });
}

/// The `max_warp_steps` budget is exact on both engines even for the
/// degenerate programs PR 4 documented as off-by-the-label-run: branches
/// into the *middle* of a consecutive-label run and trailing labels.
/// Reference count: mov(1) + first pass $A,$B,add,setp,bra (5) + three
/// re-entries $B,add,setp,bra (4 each) + bra $END (1) + $END label (1)
/// = 20 statements exactly.
#[test]
fn step_limit_exact_for_label_runs_and_trailing_labels() {
    let k = parse_kernel(
        r#"
.visible .entry lbl(.param .u64 out){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, 0;
$A:
$B:
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 4;
@%p1 bra $B;
bra $END;
$END:
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut cfg = SimConfig::new(1, 1, vec![0x1000]);
    cfg.max_warp_steps = 20;
    engines_agree(&k, &cfg, mem.clone());
    cfg.max_warp_steps = 19;
    let e1 = run_reference(&k, &cfg, mem.clone()).unwrap_err();
    let e2 = run(&k, &cfg, mem.clone()).unwrap_err();
    for e in [e1, e2] {
        assert!(matches!(e, SimError::StepLimit(19)), "got {e:?}");
    }
}

/// Tracing and `--detect-races` force the per-uop path (their hooks fire
/// per micro-op): engine telemetry shows zero superblocks and the
/// `WarpEvent` stream is unchanged from the scalar engine. The plain
/// fused run on the same kernel *does* take superblocks — the positive
/// control that keeps this regression test from passing vacuously.
#[test]
fn tracing_and_race_detection_force_the_per_uop_path() {
    // straight-line body: one fused run covers essentially the whole
    // kernel (single block, so `record_trace` covers every block)
    let k = parse_kernel(
        r#"
.visible .entry sl(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<4>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
mul.lo.s32 %r2, %r1, 7;
add.s32 %r2, %r2, 3;
xor.b32 %r2, %r2, %r1;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
st.global.b32 [%rd3], %r2;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(128);
    let base = SimConfig::new(1, 32, vec![out]);

    let mut scalar_cfg = base.clone();
    scalar_cfg.record_trace = true;
    scalar_cfg.superblocks = false;
    scalar_cfg.vector = false;
    let scalar = run(&k, &scalar_cfg, mem.clone()).unwrap();

    // fused engine + tracing: per-uop fallback, identical trace
    let mut traced_cfg = base.clone();
    traced_cfg.record_trace = true;
    let traced = run(&k, &traced_cfg, mem.clone()).unwrap();
    assert_eq!(traced.stats.superblocks_entered, 0, "tracing must force per-uop");
    assert_eq!(traced.trace, scalar.trace, "fallback trace must be unchanged");
    assert_eq!(traced.mem, scalar.mem);
    assert_eq!(traced.stats, scalar.stats);

    // fused engine + race diagnostic: per-uop fallback as well
    let mut race_cfg = base.clone();
    race_cfg.detect_races = true;
    let raced = run(&k, &race_cfg, mem.clone()).unwrap();
    assert_eq!(raced.stats.superblocks_entered, 0, "detect_races must force per-uop");
    assert_eq!(raced.mem, scalar.mem);

    // positive control: no tracing, no diagnostic → superblocks taken
    let fused = run(&k, &base, mem.clone()).unwrap();
    assert!(
        fused.stats.superblocks_entered > 0,
        "plain fused run must take the fast path"
    );
    assert_eq!(fused.mem, scalar.mem);
    assert_eq!(fused.stats, scalar.stats);
}

/// Step-limit parity across the engine matrix: sweep `max_warp_steps`
/// through the whole interesting range of a label-heavy looping kernel;
/// at every value, every decoded configuration agrees with the reference
/// on pass vs `StepLimit` — the superblock bulk charge must never move
/// the value at which the budget trips.
#[test]
fn step_limit_parity_across_engine_matrix() {
    let k = parse_kernel(
        r#"
.visible .entry sw(.param .u64 out){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, 0;
$A:
$B:
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 4;
@%p1 bra $B;
bra $END;
$END:
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    for limit in 1..=22u64 {
        let mut cfg = SimConfig::new(1, 1, vec![0x1000]);
        cfg.max_warp_steps = limit;
        let want = run_reference(&k, &cfg, mem.clone());
        for (superblocks, vector, name) in ENGINES {
            let mut c = cfg.clone();
            c.superblocks = superblocks;
            c.vector = vector;
            let got = run(&k, &c, mem.clone());
            match (&want, &got) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.stats, b.stats, "limit {limit} ({name})");
                    assert_eq!(a.mem, b.mem, "limit {limit} ({name})");
                }
                (Err(SimError::StepLimit(a)), Err(SimError::StepLimit(b))) => {
                    assert_eq!(a, b, "limit {limit} ({name})");
                }
                other => panic!("limit {limit} ({name}): engines disagree: {other:?}"),
            }
        }
    }
}

/// With the `simd` feature built in, the default (fused) engine actually
/// dispatches through the wide kernels — the telemetry counter proves
/// the vector path ran, and the CPU reference proves it ran correctly.
#[cfg(feature = "simd")]
#[test]
fn vector_path_runs_under_the_simd_feature() {
    let b = suite::by_name("vecadd").unwrap();
    let (nx, ny, nz) = sim_sizes(&b);
    let w = suite::workload(&b, nx, ny, nz, 3);
    let r = run(&w.kernel, &w.cfg, w.mem.clone()).unwrap();
    assert!(
        r.stats.vector_warp_steps > 0,
        "fused engine must use the wide kernels when the feature is on"
    );
    let out = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
    for (a, e) in out.iter().zip(&w.expected) {
        assert_eq!(a.to_bits(), e.to_bits());
    }
}

/// Decoding one suite kernel of each shape and replaying it with
/// `sim_threads` larger than the grid must also hold.
#[test]
fn thread_counts_beyond_grid_are_safe() {
    let b = suite::by_name("jacobi").unwrap();
    let (nx, ny, nz) = sim_sizes(&b);
    let w = suite::workload(&b, nx, ny, nz, 11);
    let mut cfg = w.cfg.clone();
    cfg.record_trace = true;
    let base = run(&w.kernel, &cfg, w.mem.clone()).unwrap();
    for threads in [0usize, 2, 64] {
        let mut c = cfg.clone();
        c.sim_threads = threads;
        let r = run(&w.kernel, &c, w.mem.clone()).unwrap();
        assert_eq!(base.mem, r.mem);
        assert_eq!(base.stats, r.stats);
        assert_eq!(base.trace, r.trace);
    }
}
