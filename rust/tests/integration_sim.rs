//! Differential and divergence tests for the two simulator engines.
//!
//! The decoded micro-op engine (serial and parallel) must be bit-identical
//! to the reference AST walker on every observable: final global memory,
//! stats, and the block-(0,0,0) issue trace. Divergence control flow is
//! additionally pinned to hand-computed lane tables so a bug shared by
//! both engines cannot hide.

use ptxasw::coordinator::sim_sizes;
use ptxasw::ptx::parser::parse_kernel;
use ptxasw::ptx::Kernel;
use ptxasw::sim::{run, run_reference, Allocator, GlobalMem, SimConfig, SimError, SimResult};
use ptxasw::suite;
use ptxasw::util::check_cases;

/// Run all engines (reference, decoded serial, decoded on 3 and 7
/// workers) and assert bit-identical results; returns the decoded result.
fn engines_agree(k: &Kernel, cfg: &SimConfig, mem: GlobalMem) -> SimResult {
    let reference = run_reference(k, cfg, mem.clone()).expect("reference run");
    for threads in [1usize, 3, 7] {
        let mut c = cfg.clone();
        c.sim_threads = threads;
        let r = run(k, &c, mem.clone()).expect("decoded run");
        assert_eq!(reference.mem, r.mem, "GlobalMem diverged at {threads} threads");
        assert_eq!(reference.stats, r.stats, "stats diverged at {threads} threads");
        assert_eq!(reference.trace, r.trace, "trace diverged at {threads} threads");
    }
    run(k, cfg, mem).unwrap()
}

/// If/else diamond: lanes 0–15 take the `bra`, 16–31 fall through, and
/// everyone reconverges (lowest-pc-first) for a common tail.
#[test]
fn diamond_reconvergence_lane_table() {
    let k = parse_kernel(
        r#"
.visible .entry diamond(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $THEN;
mul.lo.s32 %r2, %r1, 3;
bra $JOIN;
$THEN:
add.s32 %r2, %r1, 100;
$JOIN:
add.s32 %r2, %r2, 1;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
st.global.b32 [%rd3], %r2;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(128);
    let mut cfg = SimConfig::new(1, 32, vec![out]);
    cfg.record_trace = true;
    let r = engines_agree(&k, &cfg, mem);

    let vals = r.mem.read_u32s(out, 32).unwrap();
    for t in 0..32u32 {
        let expect = if t < 16 { t + 100 + 1 } else { t * 3 + 1 };
        assert_eq!(vals[t as usize], expect, "lane {t}");
    }
    assert_eq!(r.stats.divergent_branches, 1, "only the guarded bra diverges");
    // the else-path executes first (its pc is lower), then the then-path,
    // and the tail reconverges to the full warp
    let trace = &r.trace[0];
    assert!(trace.iter().any(|e| e.active == 0xFFFF_0000));
    assert!(trace.iter().any(|e| e.active == 0x0000_FFFF));
    let tail = trace.last().unwrap();
    assert_eq!(tail.active, 0xFFFF_FFFF, "reconverged for the ret");
}

/// Per-lane loop trip counts (`(tid & 3) + 1`): looping lanes run before
/// the exited lanes' store (lowest pc first), and the store issues once
/// for the whole warp.
#[test]
fn loop_divergence_lane_table() {
    let k = parse_kernel(
        r#"
.visible .entry lp(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
and.b32 %r2, %r1, 3;
mov.u32 %r3, 0;
mov.u32 %r4, 0;
$LOOP:
add.s32 %r4, %r4, %r1;
add.s32 %r3, %r3, 1;
setp.le.s32 %p1, %r3, %r2;
@%p1 bra $LOOP;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
st.global.b32 [%rd3], %r4;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(128);
    let mut cfg = SimConfig::new(1, 32, vec![out]);
    cfg.record_trace = true;
    let r = engines_agree(&k, &cfg, mem);
    let vals = r.mem.read_u32s(out, 32).unwrap();
    for t in 0..32u32 {
        assert_eq!(vals[t as usize], t * ((t & 3) + 1), "lane {t}");
    }
    assert!(r.stats.divergent_branches >= 1);
    // every lane stores exactly once, as one warp-level issue
    let stores: Vec<_> = r.trace[0]
        .iter()
        .filter(|e| e.exec == 0xFFFF_FFFF)
        .collect();
    assert!(!stores.is_empty());
    assert_eq!(r.stats.stores, 32);
}

/// Fractional warps (`done: t >= tpb`) and negated-guard predication:
/// block of 37 threads, only odd tids store.
#[test]
fn fractional_warp_and_predicated_off_lanes() {
    let k = parse_kernel(
        r#"
.visible .entry fw(.param .u64 out){
.reg .b32 %r<6>; .reg .b64 %rd<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %tid.x;
and.b32 %r2, %r1, 1;
setp.eq.s32 %p1, %r2, 0;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
@!%p1 st.global.b32 [%rd3], %r1;
ret;
}
"#,
    )
    .unwrap();
    let mut mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4 * 64);
    mem.write_u32s(out, &vec![9999; 64]).unwrap();
    let mut cfg = SimConfig::new(1, 37, vec![out]);
    cfg.record_trace = true;
    let r = engines_agree(&k, &cfg, mem);
    let vals = r.mem.read_u32s(out, 64).unwrap();
    for t in 0..64u32 {
        let expect = if t < 37 && t % 2 == 1 { t } else { 9999 };
        assert_eq!(vals[t as usize], expect, "lane {t}");
    }
    // 18 odd tids below 37
    assert_eq!(r.stats.stores, 18);
    // two warp streams were traced (37 threads = 1 full + 1 fractional);
    // the second warp's lanes 5..31 never execute anything
    assert_eq!(r.trace.len(), 2);
    assert!(r.trace[1].iter().all(|e| e.active & 0xFFFF_FFE0 == 0));
    // the guarded store issues with a proper exec subset
    let st = r.trace[0]
        .iter()
        .find(|e| e.exec != e.active && e.exec != 0)
        .expect("guarded store event");
    assert_eq!(st.exec, 0xAAAA_AAAA, "odd lanes of warp 0");
}

/// Every block stores to the same word: deterministic last-block-wins
/// value plus a conflict count of `nblocks - 1`, identical on every
/// engine and thread count.
#[test]
fn cross_block_write_conflicts_are_counted() {
    let k = parse_kernel(
        r#"
.visible .entry clash(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %ctaid.x;
st.global.b32 [%rd1], %r1;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4);
    let cfg = SimConfig::new(4, 1, vec![out]);
    let r = engines_agree(&k, &cfg, mem);
    assert_eq!(r.mem.read_u32s(out, 1).unwrap()[0], 3, "launch order wins");
    assert_eq!(r.stats.cross_block_write_conflicts, 3);
}

/// Disjoint per-block writes must not count as conflicts.
#[test]
fn disjoint_block_writes_do_not_conflict() {
    let k = parse_kernel(
        r#"
.visible .entry dis(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<6>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, %ctaid.x;
mul.wide.s32 %rd2, %r1, 4;
add.s64 %rd3, %rd1, %rd2;
st.global.b32 [%rd3], %r1;
ret;
}
"#,
    )
    .unwrap();
    let mem = GlobalMem::new(1 << 12);
    let mut alloc = Allocator::new(&mem);
    let out = alloc.alloc(4 * 8);
    let cfg = SimConfig::new(8, 1, vec![out]);
    let r = engines_agree(&k, &cfg, mem);
    assert_eq!(
        r.mem.read_u32s(out, 8).unwrap(),
        (0..8).collect::<Vec<u32>>()
    );
    assert_eq!(r.stats.cross_block_write_conflicts, 0);
}

/// An unknown shared variable is an `UnknownVar` on both engines (the
/// decoded engine reports it eagerly at decode time).
#[test]
fn unknown_shared_var_same_error_on_both_engines() {
    let k = parse_kernel(
        r#"
.visible .entry sv(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
mov.u64 %rd1, ghost;
ret;
}
"#,
    )
    .unwrap();
    let cfg = SimConfig::new(1, 1, vec![0x1000]);
    let e1 = run_reference(&k, &cfg, GlobalMem::new(64)).unwrap_err();
    let e2 = run(&k, &cfg, GlobalMem::new(64)).unwrap_err();
    for e in [e1, e2] {
        assert!(
            matches!(&e, SimError::UnknownVar(v) if v == "ghost"),
            "want UnknownVar(ghost), got {e:?}"
        );
        assert!(e.to_string().contains("unknown shared variable"));
    }
}

/// Randomized differential: suite benchmarks with randomized seeds, run
/// through every engine, must agree bit-for-bit — and the baseline
/// kernel's output must match the workload's bit-exact CPU reference.
#[test]
fn randomized_suite_workloads_differential() {
    let benches = suite::suite();
    check_cases("sim-differential", 6, |rng| {
        for _ in 0..3 {
            let b = &benches[rng.below(benches.len() as u64) as usize];
            let (nx, ny, nz) = sim_sizes(b);
            let seed = rng.next_u64();
            let w = suite::workload(b, nx, ny, nz, seed);
            let mut cfg = w.cfg.clone();
            cfg.record_trace = true;
            let r = engines_agree(&w.kernel, &cfg, w.mem.clone());
            let out = r.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
            assert_eq!(out.len(), w.expected.len());
            for (i, (a, e)) in out.iter().zip(&w.expected).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    e.to_bits(),
                    "{}[{i}] diverged from the CPU reference (seed {seed})",
                    b.name
                );
            }
            assert_eq!(r.stats.cross_block_write_conflicts, 0, "{}", b.name);
        }
    });
}

/// Decoding one suite kernel of each shape and replaying it with
/// `sim_threads` larger than the grid must also hold.
#[test]
fn thread_counts_beyond_grid_are_safe() {
    let b = suite::by_name("jacobi").unwrap();
    let (nx, ny, nz) = sim_sizes(&b);
    let w = suite::workload(&b, nx, ny, nz, 11);
    let mut cfg = w.cfg.clone();
    cfg.record_trace = true;
    let base = run(&w.kernel, &cfg, w.mem.clone()).unwrap();
    for threads in [0usize, 2, 64] {
        let mut c = cfg.clone();
        c.sim_threads = threads;
        let r = run(&w.kernel, &c, w.mem.clone()).unwrap();
        assert_eq!(base.mem, r.mem);
        assert_eq!(base.stats, r.stats);
        assert_eq!(base.trace, r.trace);
    }
}
