//! Validation-ladder rung 2 (DESIGN.md): the symbolic emulator and the
//! concrete warp simulator implement the *same* PTX semantics.
//!
//! For random straight-line integer kernels, the value term the emulator
//! derives for the final store — evaluated under a concrete assignment of
//! parameters/thread ids, with load-UFs reading the same concrete memory —
//! must equal what the simulator actually stored, lane by lane.
//!
//! Also: the shuffle-delta procedure agrees with brute force over
//! candidate N on randomized affine addresses.

use ptxasw::emu::emulate;
use ptxasw::ptx::parser::parse_kernel;
use ptxasw::sim::{run, Allocator, GlobalMem, SimConfig, GLOBAL_BASE};
use ptxasw::sym::{eval, solve_delta, BvOp, SymId, TermPool, UfId};
use ptxasw::util::{check_cases, Rng};

/// Build a random straight-line kernel over s32/u32 arithmetic seeded from
/// two scalar params and the thread id; stores one result per thread.
fn random_kernel(rng: &mut Rng, nops: usize) -> String {
    let ops32 = [
        ("add.s32", 2),
        ("sub.s32", 2),
        ("mul.lo.s32", 2),
        ("and.b32", 2),
        ("or.b32", 2),
        ("xor.b32", 2),
        ("min.s32", 2),
        ("max.s32", 2),
        ("min.u32", 2),
        ("max.u32", 2),
        ("shr.s32", 2),
        ("shr.u32", 2),
        ("not.b32", 1),
        ("neg.s32", 1),
    ];
    // registers %r1..%r4 hold live values; each op overwrites a random one
    let mut body = String::new();
    for _ in 0..nops {
        let (op, arity) = *rng.pick(&ops32);
        let dst = 1 + rng.below(4);
        let a = 1 + rng.below(4);
        if arity == 2 {
            // second operand: register or small immediate (shift-safe)
            if rng.bool() {
                let b = 1 + rng.below(4);
                body.push_str(&format!("{op} %r{dst}, %r{a}, %r{b};\n"));
            } else {
                let imm = if op.starts_with("shr") {
                    rng.below(31) as i64
                } else {
                    rng.range_i64(-64, 64)
                };
                body.push_str(&format!("{op} %r{dst}, %r{a}, {imm};\n"));
            }
        } else {
            body.push_str(&format!("{op} %r{dst}, %r{a};\n"));
        }
        // occasionally a mad / selp / setp tangle
        if rng.below(5) == 0 {
            let c = 1 + rng.below(4);
            body.push_str(&format!(
                "mad.lo.s32 %r{dst}, %r{a}, %r{c}, %r{};\n",
                1 + rng.below(4)
            ));
        }
        if rng.below(6) == 0 {
            let x = 1 + rng.below(4);
            let y = 1 + rng.below(4);
            body.push_str(&format!("setp.lt.s32 %p1, %r{x}, %r{y};\n"));
            body.push_str(&format!("selp.b32 %r{dst}, %r{x}, %r{y}, %p1;\n"));
        }
    }
    format!(
        r#"
.visible .entry rprog(.param .u64 out, .param .u64 a, .param .u32 s0, .param .u32 s1){{
.reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r1, [s0];
ld.param.u32 %r2, [s1];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r3, %tid.x;
mul.wide.u32 %rd5, %r3, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.b32 %r4, [%rd6];
{body}add.s64 %rd7, %rd4, %rd5;
st.global.b32 [%rd7], %r1;
ret;
}}
"#
    )
}

#[test]
fn prop_symbolic_matches_concrete() {
    check_cases("symbolic-vs-concrete", 60, |rng: &mut Rng| {
        let nops = 4 + rng.below(8) as usize;
        let src = random_kernel(rng, nops);
        let k = parse_kernel(&src).unwrap_or_else(|e| panic!("{e}\n{src}"));

        // concrete run: 1 warp
        let mut mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let out = alloc.alloc(4 * 32);
        let a = alloc.alloc(4 * 32);
        let avals: Vec<u32> = (0..32).map(|_| rng.next_u32()).collect();
        mem.write_u32s(a, &avals).unwrap();
        let s0 = rng.next_u32() as u64;
        let s1 = rng.next_u32() as u64;
        let cfg = SimConfig::new(1, 32, vec![out, a, s0, s1]);
        let r = run(&k, &cfg, mem).unwrap();
        let got = r.mem.read_u32s(out, 32).unwrap();

        // symbolic run: single flow, take the store's value term
        let res = emulate(&k).unwrap();
        assert_eq!(res.flows.len(), 1, "straight-line kernel");
        let store = res.flows[0]
            .trace
            .stores
            .last()
            .expect("one store recorded");
        let value_term = store.value;

        // evaluate the term for each lane under the concrete assignment
        let pool: &TermPool = &res.pool;
        for lane in 0..32u64 {
            let sym_val = |s: SymId| -> u64 {
                match pool.sym_name(s) {
                    "tid.x" => lane,
                    "ntid.x" => 32,
                    "ctaid.x" => 0,
                    "nctaid.x" => 1,
                    "param.out" => out,
                    "param.a" => a,
                    "param.s0" => s0,
                    "param.s1" => s1,
                    other => panic!("unexpected symbol `{other}`"),
                }
            };
            let uf_val = |f: UfId, args: &[u64]| -> u64 {
                let name = pool.uf_name(f);
                assert!(
                    name.starts_with("load.global"),
                    "unexpected UF `{name}`"
                );
                let addr = args[0];
                assert!(addr >= GLOBAL_BASE);
                // read the ORIGINAL memory (loads precede the store)
                let idx = ((addr - a) / 4) as usize;
                avals[idx] as u64
            };
            let want = eval(pool, value_term, &sym_val, &uf_val) as u32;
            assert_eq!(
                got[lane as usize], want,
                "lane {lane} diverged\n{src}"
            );
        }
    });
}

#[test]
fn prop_delta_solver_matches_brute_force() {
    check_cases("delta-brute-force", 200, |rng: &mut Rng| {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let other = p.symbol("other", 64);

        // two random affine addresses over {base|other} + stride*tid + off
        let mk = |p: &mut TermPool, use_other: bool, stride: i64, off: i64| {
            let b = if use_other { other } else { base };
            let tw = p.sext(tid, 64);
            let c = p.constant(stride as u64, 64);
            let s = p.bin(BvOp::Mul, tw, c);
            let t = p.bin(BvOp::Add, b, s);
            let o = p.constant(off as u64, 64);
            p.bin(BvOp::Add, t, o)
        };
        let stride_a = *rng.pick(&[4i64, 8, 4, 4]);
        let stride_b = if rng.below(8) == 0 { 8 } else { stride_a };
        let off_a = rng.range_i64(-40, 40) * 4;
        let off_b = rng.range_i64(-40, 40) * 4;
        let cross = rng.below(8) == 0;
        let a_addr = mk(&mut p, false, stride_a, off_a);
        let b_addr = mk(&mut p, cross, stride_b, off_b);

        let got = solve_delta(&p, a_addr, b_addr, tid);

        // brute force: N valid iff A(t+N) == B(t) for all t, checked by
        // evaluating both terms under several random assignments
        let mut brute: Option<i64> = None;
        'n: for n in -31i64..=31 {
            for _ in 0..4 {
                let base_v = rng.next_u64() & 0xFFFF_FFF0;
                let other_v = rng.next_u64() & 0xFFFF_FFF0;
                let t = rng.below(1 << 20) as u64;
                let sv_a = |s: SymId| match p.sym_name(s) {
                    "tid.x" => t.wrapping_add(n as u64),
                    "base" => base_v,
                    "other" => other_v,
                    _ => unreachable!(),
                };
                let sv_b = |s: SymId| match p.sym_name(s) {
                    "tid.x" => t,
                    "base" => base_v,
                    "other" => other_v,
                    _ => unreachable!(),
                };
                let uf = |_: UfId, _: &[u64]| 0u64;
                if eval(&p, a_addr, &sv_a, &uf) != eval(&p, b_addr, &sv_b, &uf) {
                    continue 'n;
                }
            }
            brute = Some(n);
            break;
        }
        assert_eq!(
            got, brute,
            "strides {stride_a}/{stride_b} offs {off_a}/{off_b} cross {cross}"
        );
    });
}
