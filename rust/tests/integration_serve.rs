//! End-to-end `serve` mode: a poisoned batch (parse errors, malformed
//! JSON, injected panics, flow blowups) degrades per-request while every
//! healthy kernel's rewritten PTX stays bit-exact with a direct pipeline
//! run — warm or cold, with or without a shared disk store.

use ptxasw::pipeline::{DiskStore, Pipeline, ServeOpts, ServeSession, DEFAULT_MAX_BYTES};
use ptxasw::ptx::{parse, print_module};
use ptxasw::shuffle::{DetectOpts, ElimOpts, Variant};
use ptxasw::util::Json;
use std::path::PathBuf;
use std::sync::Arc;

const STENCIL: &str = r#"
.version 7.6
.target sm_70
.address_size 64
.visible .entry stencil3(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<6>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
ld.global.nc.f32 %f3, [%rd6+8];
add.f32 %f4, %f1, %f2;
add.f32 %f5, %f4, %f3;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f5;
ret;
}
"#;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ptxasw-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// What `ptxasw asm` (defaults) would print for `src` — the serial
/// ground truth the served responses must match byte-for-byte.
fn expected_asm(src: &str) -> String {
    let p = Pipeline::new();
    let mut module = parse(src).unwrap();
    let opts = DetectOpts {
        max_abs_delta: 31,
        ..DetectOpts::default()
    };
    let elim = ElimOpts {
        enabled: true,
        block: 32,
    };
    for k in module.kernels.iter_mut() {
        let parsed = p.intake(k.clone());
        let s = p
            .synthesized_hashed(&parsed.kernel, parsed.hash, opts, Variant::Full, elim)
            .unwrap();
        *k = (*s.kernel).clone();
    }
    print_module(&module)
}

fn run_session(session: &mut ServeSession, lines: &[String]) -> Vec<Json> {
    let input = lines.join("\n");
    let mut out = Vec::new();
    session
        .serve(std::io::Cursor::new(input), &mut out)
        .expect("in-memory serve IO cannot fail");
    String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).expect("response lines are valid JSON"))
        .collect()
}

fn asm_req(id: u64, ptx: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("cmd", Json::str("asm")),
        ("ptx", Json::str(ptx)),
    ])
    .render()
}

fn err_kind(r: &Json) -> Option<&str> {
    r.get("error")?.get("kind")?.as_str()
}

/// The acceptance batch: adversarial requests interleaved with healthy
/// ones; every healthy result bit-exact with the serial ground truth,
/// every failure a typed record, the session alive throughout.
#[test]
fn poisoned_batch_serves_healthy_kernels_bit_exactly() {
    let expected = expected_asm(STENCIL);
    let mut s = ServeSession::new(
        ServeOpts {
            allow_test_faults: true,
            ..ServeOpts::default()
        },
        None,
    );
    let lines = vec![
        asm_req(1, STENCIL),
        r#"{"id":2,"cmd":"asm","ptx":"garbage that is not ptx"}"#.to_string(),
        r#"{"id":3,"cmd":"__panic"}"#.to_string(),
        "{not json".to_string(),
        r#"{"id":5,"cmd":"asm","ptx":".version 7.6","deadline_ms":0}"#.to_string(),
        r#"{"id":6,"cmd":"nonsense"}"#.to_string(),
        asm_req(7, STENCIL),
    ];
    let rs = run_session(&mut s, &lines);
    assert_eq!(rs.len(), 7, "one response line per request line");

    assert_eq!(rs[0].get("ptx").unwrap().as_str(), Some(expected.as_str()));
    assert_eq!(err_kind(&rs[1]), Some("ParseError"));
    assert_eq!(err_kind(&rs[2]), Some("Panicked"));
    assert_eq!(err_kind(&rs[3]), Some("BadRequest"));
    assert_eq!(err_kind(&rs[4]), Some("Timeout"));
    assert_eq!(err_kind(&rs[5]), Some("BadRequest"));
    // after a panic (pipelines rebuilt) the same kernel still comes out
    // bit-identical
    assert_eq!(rs[6].get("ptx").unwrap().as_str(), Some(expected.as_str()));

    let stats = s.stats();
    assert_eq!(stats.requests, 7);
    assert_eq!(stats.ok, 2);
    assert_eq!(stats.errors, 5);
    assert_eq!(stats.panicked, 1);
    // ids echo verbatim, including across error records
    assert_eq!(rs[4].get("id").unwrap().as_u64(), Some(5));
    assert_eq!(rs[3].get("id"), Some(&Json::Null));
}

/// Serve sessions sharing a cache directory behave like one process: the
/// second session's identical request is served from disk (zero
/// emulations) and bit-exact.
#[test]
fn serve_sessions_share_the_disk_store() {
    let dir = tmpdir("warm");
    let expected = expected_asm(STENCIL);

    let store = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut s1 = ServeSession::new(ServeOpts::default(), Some(store));
    let r1 = run_session(&mut s1, &[asm_req(1, STENCIL)]);
    assert_eq!(r1[0].get("ptx").unwrap().as_str(), Some(expected.as_str()));
    assert!(
        s1.pipeline().stats().disk.stores > 0,
        "the cold session must persist artifacts"
    );

    // a fresh session over a fresh store handle — the stand-in for a
    // second process on the same cache dir
    let store2 = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut s2 = ServeSession::new(ServeOpts::default(), Some(store2));
    let r2 = run_session(&mut s2, &[asm_req(1, STENCIL)]);
    assert_eq!(r2[0].get("ptx").unwrap().as_str(), Some(expected.as_str()));
    let stats = s2.pipeline().stats();
    assert!(stats.disk.hits > 0, "the warm session must hit the disk store");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The `bench` command runs a full suite benchmark (detect → synthesize →
/// validate) on the persistent session and reports per-variant validity.
#[test]
fn bench_command_reports_variant_validity() {
    let mut s = ServeSession::new(ServeOpts::default(), None);
    let lines = vec![
        r#"{"id":1,"cmd":"bench","bench":"vecadd"}"#.to_string(),
        r#"{"id":2,"cmd":"bench","bench":"no-such-bench"}"#.to_string(),
    ];
    let rs = run_session(&mut s, &lines);
    assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(true));
    assert!(rs[0].get("shuffles").unwrap().as_u64().unwrap() >= 1);
    let variants = rs[0].get("variants").unwrap().as_arr().unwrap();
    let valid_of = |name: &str| {
        variants
            .iter()
            .find(|v| v.get("variant").unwrap().as_str() == Some(name))
            .unwrap()
            .get("valid")
            .unwrap()
            .as_bool()
    };
    assert_eq!(valid_of("full"), Some(true), "paper variant validates");
    assert_eq!(valid_of("noload"), Some(false), "ablation must fail validation");
    assert_eq!(err_kind(&rs[1]), Some("BadRequest"));
}

/// A flow-explosion kernel: `bits` tid-dependent branches with distinct
/// accumulator values per path, so 2^bits environments defeat
/// memoization. 10 bits = 1024 flows — over the tight serve budget
/// (512), under the default wide one (4096): the widen/resume path.
fn forky(bits: usize) -> String {
    let mut body = String::new();
    for i in 0..bits {
        body.push_str(&format!(
            "and.b32 %r10, %r1, {};\nsetp.eq.s32 %p{p}, %r10, 0;\n\
             @%p{p} bra $S{i};\nadd.s32 %r2, %r2, {};\n$S{i}:\n",
            1u32 << i,
            100 + i,
            p = i + 1,
        ));
    }
    format!(
        ".version 7.6\n.target sm_70\n.address_size 64\n\
         .visible .entry forky(.param .u64 out){{\n\
         .reg .pred %p<{}>; .reg .b32 %r<12>; .reg .b64 %rd<3>;\n\
         ld.param.u64 %rd1, [out];\ncvta.to.global.u64 %rd2, %rd1;\n\
         mov.u32 %r1, %tid.x;\nmov.u32 %r2, 0;\n{body}\
         st.global.u32 [%rd2], %r2;\nret;\n}}\n",
        bits + 2,
    )
}

/// N concurrent socket connections, each streaming a seeded-random
/// poisoned batch — garbage PTX, `__panic`, a fork explosion, a
/// zero-deadline request — interleaved with healthy kernels. Every
/// connection's full response stream must be byte-identical to a serial
/// run of the same batch, cold and warm, and the per-connection worker
/// stats must fold back into the root session.
#[cfg(unix)]
#[test]
fn concurrent_socket_connections_isolate_poison_and_stay_bit_exact() {
    use ptxasw::util::Rng;
    use std::io::{Read as _, Write as _};
    use std::os::unix::net::UnixStream;

    let dir = tmpdir("sockrace");
    let opts = ServeOpts {
        allow_test_faults: true,
        ..ServeOpts::default()
    };

    let zero_deadline = Json::obj(vec![
        ("id", Json::num(93.0)),
        ("cmd", Json::str("asm")),
        ("ptx", Json::str(STENCIL)),
        ("deadline_ms", Json::num(0.0)),
    ])
    .render();
    let poison = [
        r#"{"id":90,"cmd":"asm","ptx":"garbage that is not ptx"}"#.to_string(),
        r#"{"id":91,"cmd":"__panic"}"#.to_string(),
        asm_req(92, &forky(10)),
        zero_deadline,
    ];
    let mut rng = Rng::new(0xC0FFEE);
    let batches: Vec<Vec<String>> = (0..4u64)
        .map(|c| {
            let mut lines = vec![asm_req(c * 10, STENCIL)];
            let mut pool: Vec<String> = poison.to_vec();
            while !pool.is_empty() {
                let i = rng.below(pool.len() as u64) as usize;
                lines.push(pool.remove(i));
                lines.push(asm_req(c * 10 + lines.len() as u64, STENCIL));
            }
            lines
        })
        .collect();

    // serial ground truth per batch: a fresh session, no store
    let expected: Vec<String> = batches
        .iter()
        .map(|lines| {
            let mut s = ServeSession::new(opts, None);
            let mut out = Vec::new();
            s.serve(std::io::Cursor::new(lines.join("\n")), &mut out)
                .unwrap();
            String::from_utf8(out).unwrap()
        })
        .collect();

    // cold phase over an empty cache dir, warm phase over the same dir
    for phase in ["cold", "warm"] {
        let sock = std::env::temp_dir().join(format!(
            "ptxasw-sockrace-{phase}-{}.sock",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&sock);
        let store = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
        let mut session = ServeSession::new(opts, Some(store));
        let spath = sock.clone();
        let server = std::thread::spawn(move || {
            ptxasw::pipeline::serve::serve_unix(&mut session, &spath).unwrap();
            session
        });
        for _ in 0..500 {
            if sock.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }

        let got: Vec<String> = std::thread::scope(|scope| {
            let handles: Vec<_> = batches
                .iter()
                .map(|lines| {
                    let sock = sock.clone();
                    scope.spawn(move || {
                        let mut stream = UnixStream::connect(&sock).expect("connect");
                        stream.write_all(lines.join("\n").as_bytes()).unwrap();
                        stream.write_all(b"\n").unwrap();
                        stream.shutdown(std::net::Shutdown::Write).unwrap();
                        let mut buf = String::new();
                        stream.read_to_string(&mut buf).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (c, (g, e)) in got.iter().zip(&expected).enumerate() {
            assert_eq!(
                g, e,
                "{phase}: connection {c}'s responses diverged from its serial run"
            );
        }

        // stop the listener and fold the workers' stats back
        let mut bye = UnixStream::connect(&sock).unwrap();
        bye.write_all(b"{\"cmd\":\"shutdown\"}\n").unwrap();
        bye.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        bye.read_to_string(&mut resp).unwrap();
        assert!(resp.contains("shutdown"), "{phase}: got {resp:?}");
        let session = server.join().unwrap();
        let stats = session.stats();
        let total: u64 = batches.iter().map(|b| b.len() as u64).sum::<u64>() + 1;
        assert_eq!(
            stats.requests, total,
            "{phase}: every worker's counters fold into the root session"
        );
        assert_eq!(
            stats.panicked, 4,
            "{phase}: one injected panic per connection"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two store handles over one directory (the stand-in for two serve
/// processes) racing stores and evictions while the Vfs seam injects
/// removal and touch-marker failures. Both handles must stay usable and
/// a clean reopen must see a coherent store whose rebuilt index agrees
/// with the ground-truth directory walk.
#[test]
fn faulted_eviction_race_between_two_sessions_keeps_the_store_coherent() {
    use ptxasw::pipeline::{KeyBuilder, StoreKind};
    use ptxasw::util::{FaultFs, FaultKind, FaultOp, FaultRule};

    let dir = tmpdir("evictrace");
    let fs = FaultFs::real();
    // the bound admits ~13 of the 900-byte artifacts, so the two writers
    // below trip evictions constantly; every few removals/touches fail
    let rules: Vec<FaultRule> = (0..40)
        .map(|i| FaultRule {
            op: FaultOp::Remove,
            nth: i * 5,
            kind: FaultKind::Error,
        })
        .chain((0..40).map(|i| FaultRule {
            op: FaultOp::Touch,
            nth: i * 7,
            kind: FaultKind::Error,
        }))
        .collect();
    let a = Arc::new(DiskStore::open_on(fs.clone(), &dir, 12_000).unwrap());
    let b = Arc::new(DiskStore::open_on(fs.clone(), &dir, 12_000).unwrap());
    fs.push_rules(&rules);
    fs.arm(true);
    std::thread::scope(|s| {
        for (t, store) in [(0u64, &a), (1, &b)] {
            s.spawn(move || {
                for i in 0..50u64 {
                    let key = KeyBuilder::new("evict-race").u64(t).u64(i).finish();
                    let payload = vec![(i % 251) as u8; 900];
                    store.store(StoreKind::Validated, key, &payload);
                }
            });
        }
    });
    fs.arm(false);
    assert!(fs.injected() > 0, "the race must actually have been faulted");

    // both handles remain usable after the storm...
    let k = KeyBuilder::new("evict-race").u64(99).u64(99).finish();
    a.store(StoreKind::Validated, k, b"alive");
    assert_eq!(
        b.load(StoreKind::Validated, k).as_deref(),
        Some(&b"alive"[..]),
        "a store written by one session must be readable by the other"
    );

    // ...and a clean reopen heals any index drift the faulted removals
    // left behind: the rebuilt index agrees with the full scan
    let clean = DiskStore::open(&dir, 1 << 20).unwrap();
    let check = clean.verify(false);
    assert!(
        check.index_mismatch.is_empty(),
        "index must agree with the directory walk after the race: {:?}",
        check.index_mismatch
    );
    // ~92k bytes were written against a 12k bound; eviction must have
    // kept running through the faults (cross-handle index drift between
    // resyncs allows a modest overshoot, never an unbounded one)
    assert!(
        check.total_bytes <= 24_000,
        "eviction kept running through the faults (resident {} bytes)",
        check.total_bytes
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Shared-memory benchmarks (cooperative scheduler, bar.sync) are
/// addressable through serve too — the session multiplexes both kernel
/// families onto one warm pipeline.
#[test]
fn bench_command_covers_shared_memory_kernels() {
    let mut s = ServeSession::new(ServeOpts::default(), None);
    let rs = run_session(
        &mut s,
        &[r#"{"id":1,"cmd":"bench","bench":"tiledreduce"}"#.to_string()],
    );
    assert_eq!(
        rs[0].get("ok").unwrap().as_bool(),
        Some(true),
        "got {:?}",
        rs[0]
    );
}
