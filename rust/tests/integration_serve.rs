//! End-to-end `serve` mode: a poisoned batch (parse errors, malformed
//! JSON, injected panics, flow blowups) degrades per-request while every
//! healthy kernel's rewritten PTX stays bit-exact with a direct pipeline
//! run — warm or cold, with or without a shared disk store.

use ptxasw::pipeline::{DiskStore, Pipeline, ServeOpts, ServeSession, DEFAULT_MAX_BYTES};
use ptxasw::ptx::{parse, print_module};
use ptxasw::shuffle::{DetectOpts, ElimOpts, Variant};
use ptxasw::util::Json;
use std::path::PathBuf;
use std::sync::Arc;

const STENCIL: &str = r#"
.version 7.6
.target sm_70
.address_size 64
.visible .entry stencil3(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<6>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
ld.global.nc.f32 %f3, [%rd6+8];
add.f32 %f4, %f1, %f2;
add.f32 %f5, %f4, %f3;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f5;
ret;
}
"#;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ptxasw-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// What `ptxasw asm` (defaults) would print for `src` — the serial
/// ground truth the served responses must match byte-for-byte.
fn expected_asm(src: &str) -> String {
    let p = Pipeline::new();
    let mut module = parse(src).unwrap();
    let opts = DetectOpts {
        max_abs_delta: 31,
        ..DetectOpts::default()
    };
    let elim = ElimOpts {
        enabled: true,
        block: 32,
    };
    for k in module.kernels.iter_mut() {
        let parsed = p.intake(k.clone());
        let s = p
            .synthesized_hashed(&parsed.kernel, parsed.hash, opts, Variant::Full, elim)
            .unwrap();
        *k = (*s.kernel).clone();
    }
    print_module(&module)
}

fn run_session(session: &mut ServeSession, lines: &[String]) -> Vec<Json> {
    let input = lines.join("\n");
    let mut out = Vec::new();
    session
        .serve(std::io::Cursor::new(input), &mut out)
        .expect("in-memory serve IO cannot fail");
    String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).expect("response lines are valid JSON"))
        .collect()
}

fn asm_req(id: u64, ptx: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("cmd", Json::str("asm")),
        ("ptx", Json::str(ptx)),
    ])
    .render()
}

fn err_kind(r: &Json) -> Option<&str> {
    r.get("error")?.get("kind")?.as_str()
}

/// The acceptance batch: adversarial requests interleaved with healthy
/// ones; every healthy result bit-exact with the serial ground truth,
/// every failure a typed record, the session alive throughout.
#[test]
fn poisoned_batch_serves_healthy_kernels_bit_exactly() {
    let expected = expected_asm(STENCIL);
    let mut s = ServeSession::new(
        ServeOpts {
            allow_test_faults: true,
            ..ServeOpts::default()
        },
        None,
    );
    let lines = vec![
        asm_req(1, STENCIL),
        r#"{"id":2,"cmd":"asm","ptx":"garbage that is not ptx"}"#.to_string(),
        r#"{"id":3,"cmd":"__panic"}"#.to_string(),
        "{not json".to_string(),
        r#"{"id":5,"cmd":"asm","ptx":".version 7.6","deadline_ms":0}"#.to_string(),
        r#"{"id":6,"cmd":"nonsense"}"#.to_string(),
        asm_req(7, STENCIL),
    ];
    let rs = run_session(&mut s, &lines);
    assert_eq!(rs.len(), 7, "one response line per request line");

    assert_eq!(rs[0].get("ptx").unwrap().as_str(), Some(expected.as_str()));
    assert_eq!(err_kind(&rs[1]), Some("ParseError"));
    assert_eq!(err_kind(&rs[2]), Some("Panicked"));
    assert_eq!(err_kind(&rs[3]), Some("BadRequest"));
    assert_eq!(err_kind(&rs[4]), Some("Timeout"));
    assert_eq!(err_kind(&rs[5]), Some("BadRequest"));
    // after a panic (pipelines rebuilt) the same kernel still comes out
    // bit-identical
    assert_eq!(rs[6].get("ptx").unwrap().as_str(), Some(expected.as_str()));

    let stats = s.stats();
    assert_eq!(stats.requests, 7);
    assert_eq!(stats.ok, 2);
    assert_eq!(stats.errors, 5);
    assert_eq!(stats.panicked, 1);
    // ids echo verbatim, including across error records
    assert_eq!(rs[4].get("id").unwrap().as_u64(), Some(5));
    assert_eq!(rs[3].get("id"), Some(&Json::Null));
}

/// Serve sessions sharing a cache directory behave like one process: the
/// second session's identical request is served from disk (zero
/// emulations) and bit-exact.
#[test]
fn serve_sessions_share_the_disk_store() {
    let dir = tmpdir("warm");
    let expected = expected_asm(STENCIL);

    let store = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut s1 = ServeSession::new(ServeOpts::default(), Some(store));
    let r1 = run_session(&mut s1, &[asm_req(1, STENCIL)]);
    assert_eq!(r1[0].get("ptx").unwrap().as_str(), Some(expected.as_str()));
    assert!(
        s1.pipeline().stats().disk.stores > 0,
        "the cold session must persist artifacts"
    );

    // a fresh session over a fresh store handle — the stand-in for a
    // second process on the same cache dir
    let store2 = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut s2 = ServeSession::new(ServeOpts::default(), Some(store2));
    let r2 = run_session(&mut s2, &[asm_req(1, STENCIL)]);
    assert_eq!(r2[0].get("ptx").unwrap().as_str(), Some(expected.as_str()));
    let stats = s2.pipeline().stats();
    assert!(stats.disk.hits > 0, "the warm session must hit the disk store");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The `bench` command runs a full suite benchmark (detect → synthesize →
/// validate) on the persistent session and reports per-variant validity.
#[test]
fn bench_command_reports_variant_validity() {
    let mut s = ServeSession::new(ServeOpts::default(), None);
    let lines = vec![
        r#"{"id":1,"cmd":"bench","bench":"vecadd"}"#.to_string(),
        r#"{"id":2,"cmd":"bench","bench":"no-such-bench"}"#.to_string(),
    ];
    let rs = run_session(&mut s, &lines);
    assert_eq!(rs[0].get("ok").unwrap().as_bool(), Some(true));
    assert!(rs[0].get("shuffles").unwrap().as_u64().unwrap() >= 1);
    let variants = rs[0].get("variants").unwrap().as_arr().unwrap();
    let valid_of = |name: &str| {
        variants
            .iter()
            .find(|v| v.get("variant").unwrap().as_str() == Some(name))
            .unwrap()
            .get("valid")
            .unwrap()
            .as_bool()
    };
    assert_eq!(valid_of("full"), Some(true), "paper variant validates");
    assert_eq!(valid_of("noload"), Some(false), "ablation must fail validation");
    assert_eq!(err_kind(&rs[1]), Some("BadRequest"));
}

/// Shared-memory benchmarks (cooperative scheduler, bar.sync) are
/// addressable through serve too — the session multiplexes both kernel
/// families onto one warm pipeline.
#[test]
fn bench_command_covers_shared_memory_kernels() {
    let mut s = ServeSession::new(ServeOpts::default(), None);
    let rs = run_session(
        &mut s,
        &[r#"{"id":1,"cmd":"bench","bench":"tiledreduce"}"#.to_string()],
    );
    assert_eq!(
        rs[0].get("ok").unwrap().as_bool(),
        Some(true),
        "got {:?}",
        rs[0]
    );
}
