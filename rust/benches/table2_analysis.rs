//! Table 2 reproduction: per-benchmark shuffle/load counts, average deltas
//! and analysis wall-time, with the paper's values side by side.
//!
//!     cargo bench --bench table2_analysis

use ptxasw::emu::emulate;
use ptxasw::shuffle::{detect, DetectOpts};
use ptxasw::suite::{generate, suite};
use std::time::Instant;

/// Paper Table 2 (name, shuffles, loads, delta, analysis seconds).
const PAPER: [(&str, usize, usize, Option<f64>, f64); 16] = [
    ("divergence", 1, 6, Some(2.00), 4.281),
    ("gameoflife", 6, 9, Some(1.50), 3.470),
    ("gaussblur", 20, 25, Some(2.50), 7.938),
    ("gradient", 1, 6, Some(2.00), 4.668),
    ("jacobi", 6, 9, Some(1.50), 4.119),
    ("lapgsrb", 12, 25, Some(1.83), 14.296),
    ("laplacian", 2, 7, Some(1.50), 4.816),
    ("matmul", 0, 8, None, 13.971),
    ("matvec", 0, 7, None, 4.929),
    ("sincos", 0, 2, None, 101.424),
    ("tricubic", 48, 67, Some(2.00), 99.476),
    ("tricubic2", 48, 67, Some(2.00), 101.855),
    ("uxx1", 3, 17, Some(2.00), 7.466),
    ("vecadd", 0, 2, None, 3.281),
    ("wave13pt", 4, 14, Some(2.50), 6.967),
    ("whispering", 6, 19, Some(0.83), 6.288),
];

fn main() {
    println!("=== Table 2: shuffle synthesis statistics ===\n");
    println!(
        "{:<12} {:>4} {:>13} {:>6} {:>12} {:>11} {:>9}",
        "name", "Lang", "Shuffle/Load", "Delta", "Analysis", "paper(s)", "speedup"
    );
    let mut total_ours = 0f64;
    let mut total_paper = 0f64;
    let mut mismatches = 0;
    for (b, row) in suite().iter().zip(PAPER.iter()) {
        assert_eq!(b.name, row.0);
        let kernel = generate(b);
        // best-of-3 timing: emulation + detection
        let mut best = f64::MAX;
        let mut det = None;
        for _ in 0..3 {
            let t0 = Instant::now();
            let res = emulate(&kernel).expect("emulation");
            let d = detect(&kernel, &res, DetectOpts::default());
            best = best.min(t0.elapsed().as_secs_f64());
            det = Some(d);
        }
        let det = det.unwrap();
        let delta = det
            .avg_delta()
            .map(|d| format!("{d:.2}"))
            .unwrap_or_else(|| "-".into());
        let ok = det.shuffle_count() == row.1
            && det.total_global_loads == row.2
            && match (det.avg_delta(), row.3) {
                (None, None) => true,
                (Some(a), Some(b)) => (a - b).abs() < 0.01,
                _ => false,
            };
        if !ok {
            mismatches += 1;
        }
        total_ours += best;
        total_paper += row.4;
        println!(
            "{:<12} {:>4} {:>6} / {:<4} {:>6} {:>10.1}ms {:>10.1}s {:>8.0}x{}",
            b.name,
            b.lang.short(),
            det.shuffle_count(),
            det.total_global_loads,
            delta,
            best * 1e3,
            row.4,
            row.4 / best,
            if ok { "" } else { "  << MISMATCH" }
        );
    }
    println!(
        "\ntotals: ours {:.2}s vs paper {:.1}s (Racket/Rosette on i7-5930K) — {:.0}x",
        total_ours,
        total_paper,
        total_paper / total_ours
    );
    assert_eq!(mismatches, 0, "{mismatches} Table 2 rows mismatched");
    println!("table2_analysis OK — all 16 rows match the paper");
}
