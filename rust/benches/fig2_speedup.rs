//! Figure 2 reproduction: speed-up of NO LOAD / NO CORNER / PTXASW vs the
//! original, with SM occupancy, for all 16 benchmarks on all four GPU
//! generations — plus the paper's qualitative shape checks.
//!
//!     cargo bench --bench fig2_speedup

use ptxasw::coordinator::{report, run_suite, PipelineConfig};
use ptxasw::shuffle::Variant;
use ptxasw::suite::suite;

fn main() {
    let cfg = PipelineConfig {
        variants: vec![Variant::NoLoad, Variant::NoCorner, Variant::Full],
        ..PipelineConfig::default()
    };
    let benches = suite();
    let results = run_suite(&benches, &cfg);
    let ok: Vec<_> = results
        .iter()
        .map(|r| r.as_ref().expect("pipeline"))
        .collect();

    println!("=== Figure 2: speed-up vs Original ===\n");
    println!("{}", report::figure2(&ok, &cfg.archs, &cfg.variants));

    // ---- paper shape checks (who wins, where, by roughly what factor) ----
    let arch_idx = |n: &str| cfg.archs.iter().position(|a| a.name == n).unwrap();
    let (kep, max, pas, vol) = (
        arch_idx("Kepler"),
        arch_idx("Maxwell"),
        arch_idx("Pascal"),
        arch_idx("Volta"),
    );
    let get = |name: &str| ok.iter().find(|r| r.name == name).unwrap();

    // 1. zero-shuffle benchmarks are exactly flat everywhere
    for n in ["matmul", "matvec", "sincos", "vecadd"] {
        for ai in [kep, max, pas, vol] {
            let s = get(n).speedup(Variant::Full, ai).unwrap();
            assert!((s - 1.0).abs() < 1e-9, "{n}: {s}");
        }
    }
    println!("shape 1 OK: matmul/matvec/sincos/vecadd unchanged");

    // 2. Maxwell's best case is gaussblur (paper: +132%, texture stalls)
    let best_maxwell = ok
        .iter()
        .filter(|r| r.detection.shuffle_count() > 0)
        .max_by(|a, b| {
            a.speedup(Variant::Full, max)
                .partial_cmp(&b.speedup(Variant::Full, max))
                .unwrap()
        })
        .unwrap();
    assert_eq!(best_maxwell.name, "gaussblur", "Maxwell best case");
    let gb = get("gaussblur").speedup(Variant::Full, max).unwrap();
    assert!(gb > 1.3, "gaussblur Maxwell should win big, got {gb:.3}");
    println!("shape 2 OK: Maxwell peaks on gaussblur ({gb:.3}x; paper 2.32x)");

    // 3. Volta: performance degradation when >10 shuffles are generated
    for r in ok.iter().filter(|r| r.detection.shuffle_count() > 10) {
        let s = r.speedup(Variant::Full, vol).unwrap();
        assert!(s < 1.0, "{}: Volta with {} shuffles gave {s:.3}x", r.name, r.detection.shuffle_count());
    }
    println!("shape 3 OK: Volta degrades whenever >10 shuffles are placed");

    // 4. gaussblur: Volta's performance drops by roughly half of original
    let gbv = get("gaussblur").speedup(Variant::Full, vol).unwrap();
    assert!(gbv < 0.75, "gaussblur Volta {gbv:.3}");
    println!("shape 4 OK: gaussblur halves on Volta ({gbv:.3}x; paper ~0.5x)");

    // 5. per-arch average ordering: Maxwell > Pascal > Volta (paper:
    //    +10.9% / +1.8% / -15.2%); Kepler mixed (-3.3%)
    let avg = |ai: usize| -> f64 {
        let v: Vec<f64> = ok
            .iter()
            .map(|r| r.speedup(Variant::Full, ai).unwrap())
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    let (am, ap, av, ak) = (avg(max), avg(pas), avg(vol), avg(kep));
    println!(
        "averages: Kepler {ak:.3} Maxwell {am:.3} Pascal {ap:.3} Volta {av:.3} \
         (paper: 0.967 / 1.109 / 1.018 / 0.848)"
    );
    assert!(am > ap && ap > av, "Maxwell > Pascal > Volta ordering");
    assert!(am > 1.0, "Maxwell must gain on average");
    assert!(av < 1.0, "Volta must lose on average");

    // 6. NO LOAD >= PTXASW on every benchmark/arch (removing work is the
    //    upper bound of covering it)
    for r in &ok {
        for ai in [kep, max, pas, vol] {
            let nl = r.speedup(Variant::NoLoad, ai).unwrap();
            let f = r.speedup(Variant::Full, ai).unwrap();
            assert!(nl >= f - 1e-9, "{} arch{ai}: NO LOAD {nl} < PTXASW {f}", r.name);
        }
    }
    println!("shape 6 OK: NO LOAD bounds PTXASW everywhere");

    println!("\nfig2_speedup OK — paper shapes reproduced");
}
