//! Table 1 reproduction: shuffle / shared-memory / L1 latencies per GPU
//! generation, measured through the whole stack — dependent-chain
//! microbenchmark PTX (pointer-chase style, after Wong et al.) is run on
//! the warp simulator and replayed through the scoreboard model; the
//! per-step cost is the observed latency.
//!
//!     cargo bench --bench table1_latency

use ptxasw::perf::{all_archs, model};
use ptxasw::ptx::parser::parse_kernel;
use ptxasw::sim::{run, Allocator, GlobalMem, SimConfig};

const CHAIN: usize = 64;

/// A kernel whose body is a dependent chain of `op`-shaped steps.
fn chain_kernel(step: &str) -> String {
    let mut body = String::new();
    for _ in 0..CHAIN {
        body.push_str(step);
        body.push('\n');
    }
    format!(
        r#"
.visible .entry chain(.param .u64 a){{
.reg .b32 %r<8>; .reg .b64 %rd<6>; .reg .f32 %f<4>; .reg .pred %p<2>;
.shared .align 4 .b8 smem[512];
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.u32 %rd3, %r4, 4;
add.s64 %rd4, %rd2, %rd3;
mov.u32 %r1, 0;
st.shared.b32 [smem], %r1;
activemask.b32 %r2;
setp.eq.s32 %p1, %r2, %r2;
ld.global.b32 %r1, [%rd4];
{body}st.global.b32 [%rd4], %r1;
ret;
}}
"#
    )
}

/// Measure the per-step latency of a chain kernel on each architecture.
fn measure(step: &str, overhead: f64) -> Vec<f64> {
    let src = chain_kernel(step);
    let k = parse_kernel(&src).unwrap_or_else(|e| panic!("{e}\n{src}"));
    let mut mem = GlobalMem::new(1 << 14);
    let mut alloc = Allocator::new(&mem);
    let a = alloc.alloc(4 * 64);
    mem.write_u32s(a, &vec![0; 64]).unwrap();
    let mut cfg = SimConfig::new(1, 32, vec![a]);
    cfg.record_trace = true;
    let r = run(&k, &cfg, mem).unwrap();
    all_archs()
        .iter()
        .map(|arch| {
            let rep = model(&k, &r.trace, arch);
            rep.serial_cycles / CHAIN as f64 - overhead
        })
        .collect()
}

fn main() {
    println!("=== Table 1: latencies (clock cycles) per architecture ===\n");
    // dependent chains: an `and` on the previous result serializes each
    // step against the in-order scoreboard; 2 cycles of chain overhead
    // (issue + in-order slot) are subtracted below
    const OVERHEAD: f64 = 2.0;
    let shfl = measure("shfl.sync.up.b32 %r1, %r1, 0, 0, %r2;", OVERHEAD);
    let shared = measure("and.b32 %r3, %r1, 0;\nld.shared.b32 %r1, [smem];", OVERHEAD);
    // guarded loads take the cache-hit path of the model — the paper's
    // microbenchmark arrays are hot, so this measures "L1 Hit"
    let l1 = measure(
        "and.b32 %r3, %r1, 0;\n@%p1 ld.global.b32 %r1, [%rd4];",
        OVERHEAD,
    );

    let paper = [
        ("Kepler", 24, 26, 35),
        ("Maxwell", 33, 23, 82),
        ("Pascal", 33, 24, 82),
        ("Volta", 22, 19, 28),
    ];
    println!(
        "{:<9} {:>14} {:>14} {:>14}",
        "name", "Shuffle (up)", "SM Read", "L1 Hit"
    );
    println!(
        "{:<9} {:>7}/{:>6} {:>7}/{:>6} {:>7}/{:>6}",
        "", "ours", "paper", "ours", "paper", "ours", "paper"
    );
    for (i, (name, ps, pm, pl)) in paper.iter().enumerate() {
        println!(
            "{:<9} {:>7.1}/{:>6} {:>7.1}/{:>6} {:>7.1}/{:>6}",
            name, shfl[i], ps, shared[i], pm, l1[i], pl
        );
    }
    // shape assertions: orderings of Table 1 must hold in the measurement
    let volta = 3;
    for i in 0..4 {
        assert!(shfl[i] > 0.0 && shared[i] > 0.0 && l1[i] > 0.0);
        assert!(
            shfl[volta] <= shfl[i] + 1e-9,
            "Volta shuffle must be fastest"
        );
    }
    assert!(l1[1] > l1[0], "Maxwell L1 slower than Kepler");
    assert!(l1[2] > l1[3], "Pascal L1 slower than Volta");
    println!("\ntable1_latency OK (orderings hold)");
}
