//! Figure 3 reproduction: stall-reason breakdown (Original / NO LOAD /
//! NO CORNER / PTXASW, left to right) for every benchmark on each GPU.
//!
//!     cargo bench --bench fig3_stalls

use ptxasw::coordinator::{report, run_suite, PipelineConfig};
use ptxasw::perf::Stall;
use ptxasw::shuffle::Variant;
use ptxasw::suite::suite;

fn main() {
    let cfg = PipelineConfig {
        variants: vec![Variant::NoLoad, Variant::NoCorner, Variant::Full],
        ..PipelineConfig::default()
    };
    let benches = suite();
    let results = run_suite(&benches, &cfg);
    let ok: Vec<_> = results
        .iter()
        .map(|r| r.as_ref().expect("pipeline"))
        .collect();

    println!("=== Figure 3: stall breakdown per benchmark/architecture ===\n");
    for r in &ok {
        println!("{}", report::figure3(r, &cfg.archs));
    }

    // ---- paper shape checks ----
    let arch_idx = |n: &str| cfg.archs.iter().position(|a| a.name == n).unwrap();
    let max = arch_idx("Maxwell");
    let get = |name: &str| ok.iter().find(|r| r.name == name).unwrap();
    // The profiler's "texture" samples cover both the dependency wait and
    // the texture-queue back-pressure; in our model those land in the
    // Texture and MemThrottle buckets respectively — combine them.
    let tex_frac = |r: &&ptxasw::coordinator::BenchResult, ai: usize, v: Option<Variant>| -> f64 {
        let rep = match v {
            None => &r.baseline.reports[ai],
            Some(v) => &r.variants.iter().find(|(x, _)| *x == v).unwrap().1.reports[ai],
        };
        rep.stall_fractions()
            .iter()
            .filter(|(n, _)| *n == Stall::Texture.name() || *n == Stall::MemThrottle.name())
            .map(|(_, f)| *f)
            .sum()
    };

    // §8.2: gaussblur's texture stall collapses from Original to PTXASW
    // (paper: 47.5% → 5.3%)
    let gb = get("gaussblur");
    let before = tex_frac(&gb, max, None);
    let after = tex_frac(&gb, max, Some(Variant::Full));
    println!(
        "gaussblur/Maxwell texture-stall fraction: {:.1}% → {:.1}% (paper 47.5% → 5.3%)",
        before * 100.0,
        after * 100.0
    );
    assert!(before > 0.25, "original gaussblur must be texture-bound");
    assert!(after < before, "PTXASW must reduce the texture pressure");

    // §8.2: lapgsrb texture stalls also drop sharply (paper 23.0% → 0.1%)
    let lg = get("lapgsrb");
    let b2 = tex_frac(&lg, max, None);
    let a2 = tex_frac(&lg, max, Some(Variant::Full));
    println!(
        "lapgsrb/Maxwell texture-stall fraction: {:.1}% → {:.1}% (paper 23.0% → 0.1%)",
        b2 * 100.0,
        a2 * 100.0
    );
    assert!(a2 < b2, "lapgsrb texture stalls must drop");

    // memory-dependency stalls dominate the 2D streaming kernels' originals
    for name in ["jacobi", "gameoflife"] {
        let r = get(name);
        let rep = &r.baseline.reports[max];
        let fr = rep.stall_fractions();
        let texy: f64 = fr
            .iter()
            .filter(|(n, _)| {
                *n == "texture" || *n == "mem_dep" || *n == "mem_throttle"
            })
            .map(|(_, f)| f)
            .sum();
        assert!(texy > 0.2, "{name}: memory-ish stalls dominate, got {texy}");
    }
    println!("\nfig3_stalls OK — stall-shape checks hold");
}
