//! §8.5 reproduction: shuffle synthesis on application kernels (hypterm,
//! rhs4th3fort, derivative) on Pascal, restricted to |N| ≤ 1 — the paper
//! reports 12/48, 44/179 and 52/166 shuffles with 0.48% / 2.49% / 3.79%
//! speed-ups.
//!
//!     cargo bench --bench app_example

use ptxasw::coordinator::{run_benchmark, PipelineConfig};
use ptxasw::perf::by_name;
use ptxasw::shuffle::{DetectOpts, Variant};
use ptxasw::suite::apps;

fn main() {
    let cfg = PipelineConfig {
        detect: DetectOpts { max_abs_delta: 1, ..Default::default() },
        archs: vec![by_name("Pascal").unwrap()],
        ..PipelineConfig::default()
    };

    // (kernel, paper shuffles, paper loads, paper speedup %)
    let paper = [
        ("hypterm_x", 12usize, 48usize, Some(0.48)),
        ("hypterm_y", 0, 52, None),
        ("hypterm_z", 0, 52, None),
        ("rhs4th3fort", 44, 179, Some(2.49)),
        ("derivative", 52, 166, Some(3.79)),
    ];

    println!("=== §8.5: application kernels on Pascal, |N| ≤ 1 ===\n");
    println!(
        "{:<12} {:>13} {:>9} {:>10} {:>12} {:>10}",
        "kernel", "Shuffle/Load", "analysis", "speedup", "paper-shfl", "paper-spd"
    );
    for (b, (pname, pshfl, ploads, pspd)) in apps().iter().zip(paper.iter()) {
        assert_eq!(b.name, *pname);
        let r = run_benchmark(b, &cfg).expect("pipeline");
        let s = r.speedup(Variant::Full, 0).unwrap();
        // validity: PTXASW stays bit-exact even at this scale
        let full = r.variants.iter().find(|(v, _)| *v == Variant::Full).unwrap();
        assert_eq!(full.1.valid, Some(true), "{}", b.name);
        println!(
            "{:<12} {:>6} / {:<4} {:>8.1?} {:>9.3}x {:>9}/{:<4} {:>9}",
            r.name,
            r.detection.shuffle_count(),
            r.detection.total_global_loads,
            r.analysis_time,
            s,
            pshfl,
            ploads,
            pspd.map(|p| format!("+{p}%")).unwrap_or_else(|| "-".into()),
        );
        assert_eq!(r.detection.shuffle_count(), *pshfl, "{}", b.name);
        assert_eq!(r.detection.total_global_loads, *ploads, "{}", b.name);
        // deltas are all |N| = 1 where any exist
        if *pshfl > 0 {
            assert_eq!(r.detection.avg_delta(), Some(1.0), "{}", b.name);
            // paper reports small effects near break-even (+0.5..+3.8%).
            // Our model is pessimistic for many-shuffle kernels on Pascal
            // (bank-conflict latency per predicated load + register
            // pressure; §8.3's own mechanism) — see EXPERIMENTS.md. Demand
            // a sane band rather than the exact percentage.
            assert!(s > 0.4 && s < 1.35, "{}: {s}", b.name);
        }
    }
    println!("\napp_example OK — §8.5 shuffle yields match the paper");
}
