//! Ablations over the design choices DESIGN.md calls out.
//!
//!  A. §8.3 uniform-branch alternative: predicate the whole shuffle with
//!     `@%incomplete bra` — removes Pascal's register-bank-conflict
//!     latency ("Other") but adds a branch. Paper: gameoflife improves to
//!     150.8% on Pascal, yet the *average* over the suite drops to 0.88x.
//!  B. Delta-bound sweep: how |N|max trades shuffle count vs corner cost.
//!  C. Solver value: path pruning + memoization statistics per benchmark
//!     (what the SMT-lite machinery saves the emulator).
//!
//!     cargo bench --bench ablation

use ptxasw::coordinator::{run_benchmark, PipelineConfig};
use ptxasw::emu::emulate_with;
use ptxasw::perf::by_name;
use ptxasw::shuffle::{detect, DetectOpts, Variant};
use ptxasw::suite::{generate, suite};

fn main() {
    // ---- A: uniform branch vs predicated corner (Pascal) ----
    println!("=== A. §8.3: UNIFORM (branchy) vs PTXASW (predicated), Pascal ===\n");
    let cfg = PipelineConfig {
        variants: vec![Variant::Full, Variant::UniformBranch],
        archs: vec![by_name("Pascal").unwrap()],
        ..PipelineConfig::default()
    };
    let mut uni_rel = Vec::new();
    println!(
        "{:<12} {:>9} {:>9} {:>11}",
        "benchmark", "PTXASW", "UNIFORM", "uni/ptxasw"
    );
    for b in suite() {
        if b.expect_shuffles == 0 {
            continue;
        }
        let r = run_benchmark(&b, &cfg).expect("pipeline");
        let f = r.speedup(Variant::Full, 0).unwrap();
        let u = r.speedup(Variant::UniformBranch, 0).unwrap();
        // both are valid transformations
        for (_, o) in &r.variants {
            assert_eq!(o.valid, Some(true), "{}", b.name);
        }
        uni_rel.push(u / f);
        println!("{:<12} {:>8.3}x {:>8.3}x {:>10.3}", b.name, f, u, u / f);
    }
    let avg_rel: f64 = uni_rel.iter().sum::<f64>() / uni_rel.len() as f64;
    println!(
        "\nuniform-branch relative cost on average: {avg_rel:.3} (paper: 0.88x slowdown)\n"
    );

    // ---- B: delta-bound sweep on gaussblur ----
    println!("=== B. max |N| sweep (gaussblur) ===\n");
    println!("{:>6} {:>9} {:>7}", "maxN", "shuffles", "delta");
    let b = suite().into_iter().find(|b| b.name == "gaussblur").unwrap();
    let k = generate(&b);
    let res = ptxasw::emu::emulate(&k).unwrap();
    let mut prev = 0;
    for max_n in [1i64, 2, 3, 4, 8, 31] {
        let det = detect(&k, &res, DetectOpts { max_abs_delta: max_n, ..Default::default() });
        println!(
            "{:>6} {:>9} {:>7.2}",
            max_n,
            det.shuffle_count(),
            det.avg_delta().unwrap_or(0.0)
        );
        assert!(det.shuffle_count() >= prev, "monotone in the bound");
        prev = det.shuffle_count();
    }
    assert_eq!(prev, 20, "full bound recovers Table 2's 20 shuffles");

    // ---- C: what the solver machinery saves ----
    println!("\n=== C. emulator statistics: pruning + memoization ===\n");
    println!(
        "{:<12} {:>7} {:>8} {:>8} {:>8} {:>9}",
        "benchmark", "flows", "pruned", "memoized", "decided", "steps"
    );
    for b in suite() {
        let k = generate(&b);
        let res = emulate_with(&k, ptxasw::emu::Limits::default()).unwrap();
        println!(
            "{:<12} {:>7} {:>8} {:>8} {:>8} {:>9}",
            b.name,
            res.stats.flows_finished,
            res.stats.flows_pruned,
            res.stats.flows_memoized,
            res.stats.branches_decided,
            res.stats.steps
        );
        // every kernel must stay well under the flow limit — the pruning
        // and loop abstraction keep path explosion bounded
        assert!(res.stats.flows_finished < 256, "{}", b.name);
    }
    println!("\nablation OK");
}
