"""L2 model tests: scan composition + HLO lowering smoke checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape).astype(np.float32))


def test_scan_matches_iterated_step():
    x = rand(model.SHAPE2D, 7)
    scanned = model.jacobi_n_steps(x, 4)
    stepped = x
    for _ in range(4):
        stepped = model.jacobi_step(stepped)
    np.testing.assert_allclose(scanned, stepped, rtol=1e-6, atol=1e-7)


def test_wave_leapfrog_shifts_planes():
    w0 = rand(model.SHAPE3D, 8)
    w1 = rand(model.SHAPE3D, 9)
    out = model.wave_n_steps(w0, w1, 2)
    # manual unroll
    a = model.wave13pt_step(w0, w1)
    b = model.wave13pt_step(a, w0)
    np.testing.assert_allclose(out, b, rtol=1e-6, atol=1e-7)


def test_step_matches_oracle():
    x = rand(model.SHAPE2D, 10)
    np.testing.assert_allclose(
        model.jacobi_step(x), ref.jacobi_ref(x), rtol=1e-5, atol=1e-6
    )


def test_hlo_text_lowering():
    text = model.lower_to_hlo_text("jacobi")
    assert "HloModule" in text
    assert "f32[16,96]" in text
    # interpret=True must not leave a Mosaic custom-call behind
    assert "tpu_custom_call" not in text


def test_all_exports_lower():
    for name in model.EXPORTS:
        text = model.lower_to_hlo_text(name)
        assert "HloModule" in text, name
