"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes and data; fixed cases pin the exported shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-2.0, 2.0, size=shape).astype(np.float32))


STENCILS_2D = [
    ("jacobi", common.jacobi_taps, ref.jacobi_ref),
    ("gaussblur", common.gaussblur_taps, ref.gaussblur_ref),
    ("gameoflife", common.gameoflife_taps, ref.gameoflife_ref),
]

STENCILS_3D = [
    ("laplacian", common.laplacian_taps, ref.laplacian_ref),
    ("gradient", common.gradient_taps, ref.gradient_ref),
]


@pytest.mark.parametrize("name,taps,oracle", STENCILS_2D)
def test_2d_matches_ref_exported_shape(name, taps, oracle):
    x = rand((16, 96), seed=hash(name) % 2**32)
    got = common.stencil2d_pallas(taps(), x.shape)(x)
    np.testing.assert_allclose(got, oracle(x), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name,taps,oracle", STENCILS_3D)
def test_3d_matches_ref_exported_shape(name, taps, oracle):
    x = rand((8, 10, 40), seed=hash(name) % 2**32)
    got = common.stencil3d_pallas(taps(), x.shape)(x)
    np.testing.assert_allclose(got, oracle(x), rtol=1e-5, atol=1e-6)


def test_wave13pt_matches_ref():
    w0 = rand((8, 10, 40), seed=1)
    w1 = rand((8, 10, 40), seed=2)
    got = common.wave13pt_pallas(w0.shape)(w0, w1)
    np.testing.assert_allclose(got, ref.wave13pt_ref(w0, w1), rtol=1e-5, atol=1e-6)


def test_tiled_jacobi_matches_plain():
    x = rand((18, 64), seed=3)  # 16 interior rows = 2 tiles of 8
    plain = common.stencil2d_pallas(common.jacobi_taps(), x.shape)(x)
    tiled = common.stencil2d_pallas_tiled(common.jacobi_taps(), x.shape, tile_j=8)(x)
    np.testing.assert_allclose(tiled, plain, rtol=1e-6, atol=1e-7)


def test_halo_ring_is_zero():
    x = rand((12, 40), seed=4)
    out = np.asarray(common.stencil2d_pallas(common.gaussblur_taps(), x.shape)(x))
    assert (out[:2, :] == 0).all() and (out[-2:, :] == 0).all()
    assert (out[:, :2] == 0).all() and (out[:, -2:] == 0).all()
    assert np.abs(out[2:-2, 2:-2]).sum() > 0


@settings(max_examples=20, deadline=None)
@given(
    ny=st.integers(3, 24),
    nx=st.integers(3, 48),
    seed=st.integers(0, 2**31),
)
def test_prop_jacobi_shapes(ny, nx, seed):
    x = rand((ny, nx), seed)
    got = common.stencil2d_pallas(common.jacobi_taps(), x.shape)(x)
    np.testing.assert_allclose(got, ref.jacobi_ref(x), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    nz=st.integers(3, 8),
    ny=st.integers(3, 10),
    nx=st.integers(3, 24),
    seed=st.integers(0, 2**31),
)
def test_prop_laplacian_shapes(nz, ny, nx, seed):
    x = rand((nz, ny, nx), seed)
    got = common.stencil3d_pallas(common.laplacian_taps(), x.shape)(x)
    np.testing.assert_allclose(got, ref.laplacian_ref(x), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_prop_linear_in_input(seed):
    # stencils are linear: f(a·x) == a·f(x)
    x = rand((10, 32), seed)
    f = common.stencil2d_pallas(common.jacobi_taps(), x.shape)
    np.testing.assert_allclose(f(2.0 * x), 2.0 * f(x), rtol=1e-5, atol=1e-6)


def test_tap_tables_match_rust_counts():
    # keep in sync with kernelgen.rs / Table 2
    assert len(common.jacobi_taps()) == 9
    assert len(common.gaussblur_taps()) == 25
    assert len(common.gameoflife_taps()) == 9
    assert len(common.laplacian_taps()) == 7
    assert len(common.gradient_taps()) == 6
    assert len(common.wave13pt_taps()) == 13
    # gaussblur weights are a separable normalized-ish blur
    s = sum(c for _, _, c in common.gaussblur_taps())
    assert abs(s - sum((0.054, 0.244, 0.403, 0.244, 0.054)) ** 2) < 1e-6
