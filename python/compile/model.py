"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

Each benchmark's single-step function calls the L1 Pallas kernel; the
multi-step variants `lax.scan` over it (fused by XLA into one executable —
no per-step Python). The Rust coordinator loads the lowered HLO once and
drives it from the request path.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import common

# --- single-step graphs -------------------------------------------------------


def jacobi_step(x):
    return common.stencil2d_pallas(common.jacobi_taps(), x.shape)(x)


def jacobi_step_tiled(x):
    return common.stencil2d_pallas_tiled(common.jacobi_taps(), x.shape)(x)


def gaussblur_step(x):
    return common.stencil2d_pallas(common.gaussblur_taps(), x.shape)(x)


def gameoflife_step(x):
    return common.stencil2d_pallas(common.gameoflife_taps(), x.shape)(x)


def laplacian_step(x):
    return common.stencil3d_pallas(common.laplacian_taps(), x.shape)(x)


def gradient_step(x):
    return common.stencil3d_pallas(common.gradient_taps(), x.shape)(x)


def wave13pt_step(w0, w1):
    return common.wave13pt_pallas(w0.shape)(w0, w1)


# --- multi-step models (scan, not unroll: compact HLO, no recompute) ----------


def jacobi_n_steps(x, n):
    def body(carry, _):
        return jacobi_step(carry), ()

    out, _ = lax.scan(body, x, (), length=n)
    return out


def wave_n_steps(w0, w1, n):
    """Leapfrog-ish: new = stencil(w0) - w1; shift time planes."""

    def body(carry, _):
        w0, w1 = carry
        new = wave13pt_step(w0, w1)
        return (new, w0), ()

    (w0, w1), _ = lax.scan(body, (w0, w1), (), length=n)
    return w0


# --- export table --------------------------------------------------------------

# name -> (fn, example-arg shapes); shapes match the Rust e2e example
SHAPE2D = (16, 96)
SHAPE3D = (8, 10, 40)

EXPORTS = {
    "jacobi": (jacobi_step, [SHAPE2D]),
    "jacobi_tiled": (jacobi_step_tiled, [SHAPE2D]),
    "gaussblur": (gaussblur_step, [SHAPE2D]),
    "gameoflife": (gameoflife_step, [SHAPE2D]),
    "laplacian": (laplacian_step, [SHAPE3D]),
    "gradient": (gradient_step, [SHAPE3D]),
    "wave13pt": (wave13pt_step, [SHAPE3D, SHAPE3D]),
    "jacobi_x4": (lambda x: jacobi_n_steps(x, 4), [SHAPE2D]),
}


def lower_to_hlo_text(name):
    """Lower one export to HLO text (the interchange format the xla crate's
    text parser accepts — serialized protos from jax ≥ 0.5 are rejected by
    xla_extension 0.5.1; see /opt/xla-example/README.md)."""
    from jax._src.lib import xla_client as xc

    fn, shapes = EXPORTS[name]
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
