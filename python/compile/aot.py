"""AOT driver: lower every L2 export to `artifacts/<name>.hlo.txt`.

Runs once at build time (`make artifacts`); Python is never on the Rust
request path. Incremental: skips artifacts newer than the compile sources
unless `--force`.

Usage: python -m compile.aot [--out-dir ../artifacts] [--force] [names...]
"""

import argparse
import pathlib
import sys

from . import model


def _sources_mtime() -> float:
    here = pathlib.Path(__file__).parent
    return max(p.stat().st_mtime for p in here.rglob("*.py"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=str(pathlib.Path(__file__).parents[2] / "artifacts"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("names", nargs="*", help="subset of exports (default: all)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = args.names or sorted(model.EXPORTS)
    src_mtime = _sources_mtime()

    wrote = 0
    for name in names:
        if name not in model.EXPORTS:
            print(f"unknown export `{name}`; have {sorted(model.EXPORTS)}", file=sys.stderr)
            return 2
        path = out_dir / f"{name}.hlo.txt"
        if not args.force and path.exists() and path.stat().st_mtime >= src_mtime:
            print(f"  up-to-date {path.name}")
            continue
        text = model.lower_to_hlo_text(name)
        path.write_text(text)
        print(f"  wrote {path.name} ({len(text)} chars)")
        wrote += 1

    # shape manifest for the Rust runtime
    manifest = out_dir / "manifest.txt"
    lines = []
    for name in sorted(model.EXPORTS):
        _, shapes = model.EXPORTS[name]
        dims = ";".join(",".join(str(d) for d in s) for s in shapes)
        lines.append(f"{name} f32 {dims}")
    manifest.write_text("\n".join(lines) + "\n")
    print(f"  manifest: {len(lines)} entries; {wrote} artifact(s) rebuilt")
    return 0


if __name__ == "__main__":
    sys.exit(main())
