"""Shared tap-set definitions and the generic Pallas stencil kernels.

The tap sets MUST match `rust/src/suite/kernelgen.rs` exactly — the
end-to-end example (`examples/stencil_validate.rs`) runs the same stencil
three ways (PJRT-executed Pallas artifact, simulated original PTX,
simulated shuffle-synthesized PTX) and cross-checks the numerics.

Layout convention: 2D arrays are `[ny, nx]`, 3D arrays `[nz, ny, nx]`,
with the thread (leading) dimension `i` innermost — the same linearization
`idx = (k*ny + j)*nx + i` the PTX generator uses.

All Pallas kernels run with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# --- tap tables (array, di, dj, dk, coef) — keep in sync with kernelgen.rs

JACOBI_C = (0.5, 0.1, 0.025)  # center, edge, corner


def jacobi_taps():
    c0, c1, c2 = JACOBI_C
    taps = []
    for dj in (-1, 0, 1):
        for di in (-1, 0, 1):
            c = c0 if (di, dj) == (0, 0) else (c1 if abs(di) + abs(dj) == 1 else c2)
            taps.append((di, dj, c))
    return taps


def gaussblur_taps():
    w = (0.054, 0.244, 0.403, 0.244, 0.054)
    return [
        (di, dj, w[di + 2] * w[dj + 2])
        for dj in (-2, -1, 0, 1, 2)
        for di in (-2, -1, 0, 1, 2)
    ]


def gameoflife_taps():
    return [
        (di, dj, 0.5 if dj == 0 else 0.125)
        for dj in (-1, 0, 1)
        for di in (-1, 0, 1)
    ]


def laplacian_taps():
    return [
        (-1, 0, 0, 1.0),
        (0, 0, 0, -6.0),
        (1, 0, 0, 1.0),
        (0, -1, 0, 1.0),
        (0, 1, 0, 1.0),
        (0, 0, -1, 1.0),
        (0, 0, 1, 1.0),
    ]


def gradient_taps():
    return [
        (-1, 0, 0, -0.5),
        (1, 0, 0, 0.5),
        (0, -1, 0, -0.5),
        (0, 1, 0, 0.5),
        (0, 0, -1, -0.5),
        (0, 0, 1, 0.5),
    ]


def wave13pt_taps():
    taps = [(di, 0, 0, 0.1) for di in (-2, -1, 0, 1, 2)]
    taps += [(0, dj, 0, 0.05) for dj in (-2, -1, 1, 2)]
    taps += [(0, 0, dk, 0.05) for dk in (-2, -1, 1, 2)]
    return taps


# --- generic whole-block Pallas kernels -----------------------------------


def _halo2(taps):
    hi = max(abs(t[0]) for t in taps)
    hj = max(abs(t[1]) for t in taps)
    return hi, hj


def _halo3(taps):
    hi = max(abs(t[0]) for t in taps)
    hj = max(abs(t[1]) for t in taps)
    hk = max(abs(t[2]) for t in taps)
    return hi, hj, hk


def stencil2d_pallas(taps, shape, dtype=jnp.float32):
    """Whole-array Pallas stencil: `out[j,i] = Σ c·x[j+dj, i+di]` on the
    interior, zero on the halo ring."""
    ny, nx = shape
    hi, hj = _halo2(taps)

    def kernel(x_ref, o_ref):
        x = x_ref[...]
        acc = jnp.zeros((ny - 2 * hj, nx - 2 * hi), dtype)
        for di, dj, c in taps:
            sl = x[hj + dj : ny - hj + dj, hi + di : nx - hi + di]
            acc = acc + dtype(c) * sl
        out = jnp.zeros((ny, nx), dtype)
        o_ref[...] = jax.lax.dynamic_update_slice(out, acc, (hj, hi))

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((ny, nx), dtype),
        interpret=True,
    )


def stencil2d_pallas_tiled(taps, shape, tile_j=8, dtype=jnp.float32):
    """Row-tiled Pallas stencil: the HBM→VMEM schedule a real TPU would use.

    The input stays in `ANY` memory space; each grid step loads its row
    tile plus halo with `pl.load` (the explicit DMA), computes, and writes
    one output tile. This is the VMEM-halo pattern DESIGN.md maps the
    paper's register-cache insight onto.
    """
    ny, nx = shape
    hi, hj = _halo2(taps)
    inner = ny - 2 * hj
    # largest tile ≤ requested that divides the interior row count
    tile_j = next(t for t in range(min(tile_j, inner), 0, -1) if inner % t == 0)
    grid = inner // tile_j

    def kernel(x_ref, o_ref):
        j = pl.program_id(0)
        row0 = j * tile_j  # first interior row of this tile (offset by hj)
        x = x_ref[pl.dslice(row0, tile_j + 2 * hj), pl.dslice(0, nx)]
        acc = jnp.zeros((tile_j, nx - 2 * hi), dtype)
        for di, dj, c in taps:
            sl = jax.lax.dynamic_slice(
                x, (hj + dj, hi + di), (tile_j, nx - 2 * hi)
            )
            acc = acc + dtype(c) * sl
        out_tile = jnp.zeros((tile_j, nx), dtype)
        out_tile = jax.lax.dynamic_update_slice(out_tile, acc, (0, hi))
        o_ref[pl.dslice(row0 + hj, tile_j), pl.dslice(0, nx)] = out_tile
        # first/last grid steps also zero the halo rings (the output
        # buffer is uninitialized in ANY memory space)
        @pl.when(j == 0)
        def _():
            o_ref[pl.dslice(0, hj), pl.dslice(0, nx)] = jnp.zeros((hj, nx), dtype)

        @pl.when(j == grid - 1)
        def _():
            o_ref[pl.dslice(ny - hj, hj), pl.dslice(0, nx)] = jnp.zeros(
                (hj, nx), dtype
            )

    def run(x):
        # zero-init output so the halo ring is well-defined
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            out_shape=jax.ShapeDtypeStruct((ny, nx), dtype),
            interpret=True,
        )(x)

    return run


def stencil3d_pallas(taps, shape, dtype=jnp.float32):
    """Whole-array 3D Pallas stencil over `[nz, ny, nx]`."""
    nz, ny, nx = shape
    hi, hj, hk = _halo3(taps)

    def kernel(x_ref, o_ref):
        x = x_ref[...]
        acc = jnp.zeros((nz - 2 * hk, ny - 2 * hj, nx - 2 * hi), dtype)
        for di, dj, dk, c in taps:
            sl = x[
                hk + dk : nz - hk + dk,
                hj + dj : ny - hj + dj,
                hi + di : nx - hi + di,
            ]
            acc = acc + dtype(c) * sl
        out = jnp.zeros((nz, ny, nx), dtype)
        o_ref[...] = jax.lax.dynamic_update_slice(out, acc, (hk, hj, hi))

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), dtype),
        interpret=True,
    )


def wave13pt_pallas(shape, dtype=jnp.float32):
    """Two-input wave kernel: 13-point stencil of w0 minus the previous
    time step w1 (tap coef -1.0), matching the Rust benchmark."""
    nz, ny, nx = shape
    taps = wave13pt_taps()
    hi, hj, hk = _halo3(taps)

    def kernel(w0_ref, w1_ref, o_ref):
        w0 = w0_ref[...]
        w1 = w1_ref[...]
        acc = jnp.zeros((nz - 2 * hk, ny - 2 * hj, nx - 2 * hi), dtype)
        for di, dj, dk, c in taps:
            sl = w0[
                hk + dk : nz - hk + dk,
                hj + dj : ny - hj + dj,
                hi + di : nx - hi + di,
            ]
            acc = acc + dtype(c) * sl
        acc = acc + dtype(-1.0) * w1[hk : nz - hk, hj : ny - hj, hi : nx - hi]
        out = jnp.zeros((nz, ny, nx), dtype)
        o_ref[...] = jax.lax.dynamic_update_slice(out, acc, (hk, hj, hi))

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), dtype),
        interpret=True,
    )


# convenience constructors per benchmark --------------------------------------

jacobi = partial(lambda shape, **kw: stencil2d_pallas(jacobi_taps(), shape, **kw))
jacobi_tiled = partial(
    lambda shape, **kw: stencil2d_pallas_tiled(jacobi_taps(), shape, **kw)
)
gaussblur = partial(lambda shape, **kw: stencil2d_pallas(gaussblur_taps(), shape, **kw))
gameoflife = partial(
    lambda shape, **kw: stencil2d_pallas(gameoflife_taps(), shape, **kw)
)
laplacian = partial(lambda shape, **kw: stencil3d_pallas(laplacian_taps(), shape, **kw))
gradient = partial(lambda shape, **kw: stencil3d_pallas(gradient_taps(), shape, **kw))
wave13pt = partial(lambda shape, **kw: wave13pt_pallas(shape, **kw))
