"""Pure-jnp correctness oracle for every Pallas kernel.

Written independently of the Pallas implementations (jnp.roll + interior
masks instead of dynamic_update_slice) so a bug in the kernels cannot be
mirrored here.
"""

import jax.numpy as jnp

from . import common


def _interior_mask2(shape, hi, hj):
    ny, nx = shape
    m = jnp.zeros(shape, bool)
    return m.at[hj : ny - hj, hi : nx - hi].set(True)


def stencil2d_ref(taps, x):
    hi = max(abs(t[0]) for t in taps)
    hj = max(abs(t[1]) for t in taps)
    acc = jnp.zeros_like(x)
    for di, dj, c in taps:
        acc = acc + jnp.float32(c) * jnp.roll(x, (-dj, -di), axis=(0, 1))
    return jnp.where(_interior_mask2(x.shape, hi, hj), acc, 0.0)


def stencil3d_ref(taps, x):
    nz, ny, nx = x.shape
    hi = max(abs(t[0]) for t in taps)
    hj = max(abs(t[1]) for t in taps)
    hk = max(abs(t[2]) for t in taps)
    acc = jnp.zeros_like(x)
    for di, dj, dk, c in taps:
        acc = acc + jnp.float32(c) * jnp.roll(x, (-dk, -dj, -di), axis=(0, 1, 2))
    m = jnp.zeros(x.shape, bool)
    m = m.at[hk : nz - hk, hj : ny - hj, hi : nx - hi].set(True)
    return jnp.where(m, acc, 0.0)


def jacobi_ref(x):
    return stencil2d_ref(common.jacobi_taps(), x)


def gaussblur_ref(x):
    return stencil2d_ref(common.gaussblur_taps(), x)


def gameoflife_ref(x):
    return stencil2d_ref(common.gameoflife_taps(), x)


def laplacian_ref(x):
    return stencil3d_ref(common.laplacian_taps(), x)


def gradient_ref(x):
    return stencil3d_ref(common.gradient_taps(), x)


def wave13pt_ref(w0, w1):
    taps = common.wave13pt_taps()
    acc = stencil3d_ref(taps, w0)
    nz, ny, nx = w0.shape
    hi = max(abs(t[0]) for t in taps)
    hj = max(abs(t[1]) for t in taps)
    hk = max(abs(t[2]) for t in taps)
    m = jnp.zeros(w0.shape, bool)
    m = m.at[hk : nz - hk, hj : ny - hj, hi : nx - hi].set(True)
    return jnp.where(m, acc - w1, 0.0)
